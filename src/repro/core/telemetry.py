"""Per-segment timing decomposition and the turnaround ledger (paper §4.2.1).

The paper decomposes each video's life into six time types measured in ms:

  download    dash cam -> master (simulated 350 ms at 1 s granularity)
  transfer    master -> worker video payload
  return      worker -> master result payload
  processing  frame extraction + inference + result write
  wait        arrival at device -> processing start (queueing + system)
  overhead    residual: turnaround - (sum of the above)

``turnaround`` is download-start -> result-at-master; *near real-time* means
turnaround <= video length.  The ledger reproduces the paper's per-device
averages (Tables 4.2-4.7) and the skip-rate accounting (§4.2.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.obs.sketch import QuantileSketch

MS = float


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default method), 0.0 for
    an empty series — telemetry stays dependency-free."""
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    rank = (len(xs) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    return float(xs[lo] + (xs[hi] - xs[lo]) * (rank - lo))


@dataclass
class SegmentRecord:
    video_id: str
    stream: str                     # "outer" | "inner"
    device: str
    download_ms: MS = 0.0
    transfer_ms: MS = 0.0
    return_ms: MS = 0.0
    processing_ms: MS = 0.0
    wait_ms: MS = 0.0
    overhead_ms: MS = 0.0
    turnaround_ms: MS = 0.0
    video_len_ms: MS = 0.0
    esd: float = 0.0
    frames_total: int = 0
    frames_processed: int = 0
    # Explicit skip decomposition (None = producer does not account per
    # cause, e.g. the EDARuntime cost model, where skipped is simply
    # total - processed).  Producers that do account (VisionServeEngine)
    # must satisfy processed + gated + dropped == total — Ledger.check().
    frames_gated: Optional[int] = None      # motion-gate rejects
    frames_dropped: Optional[int] = None    # deadline + backpressure + churn
    frames_deadline_dropped: Optional[int] = None  # subset of dropped
    # time-to-first-result: prompt-prefill TTFT for token workloads, 0.0
    # when the producer does not measure it (vision streams, EDARuntime)
    ttft_ms: MS = 0.0
    is_master: bool = False
    energy_j: float = 0.0

    @property
    def frames_skipped(self) -> int:
        return self.frames_total - self.frames_processed

    @property
    def skip_rate(self) -> float:
        if self.frames_total == 0:
            return 0.0
        return self.frames_skipped / self.frames_total

    @property
    def real_time(self) -> bool:
        return self.turnaround_ms <= self.video_len_ms

    def close(self, turnaround_ms: MS) -> None:
        """Set turnaround and derive overhead as the residual (§4.2.1)."""
        self.turnaround_ms = turnaround_ms
        accounted = (self.download_ms + self.transfer_ms + self.return_ms
                     + self.processing_ms + self.wait_ms)
        self.overhead_ms = max(turnaround_ms - accounted, 0.0)


@dataclass
class DeviceSummary:
    device: str
    is_master: bool
    n: int
    download_ms: MS
    transfer_ms: MS
    return_ms: MS
    processing_ms: MS
    wait_ms: MS
    overhead_ms: MS
    turnaround_ms: MS
    esd: float
    skip_rate: float
    avg_power_mw: float
    energy_j: float
    # mean TTFT over the records that measured one (token workloads);
    # 0.0 for pure-vision devices
    ttft_ms: MS = 0.0

    def row(self) -> dict:
        return {
            "device": self.device + ("*" if self.is_master else ""),
            "download_ms": round(self.download_ms),
            "transfer_ms": round(self.transfer_ms),
            "return_ms": round(self.return_ms),
            "processing_ms": round(self.processing_ms),
            "wait_ms": round(self.wait_ms),
            "overhead_ms": round(self.overhead_ms),
            "turnaround_ms": round(self.turnaround_ms),
            "ttft_ms": round(self.ttft_ms),
            "esd": self.esd,
            "skip_rate": f"{100 * self.skip_rate:.1f}%",
            "avg_power_mw": round(self.avg_power_mw, 1),
            "energy_j": round(self.energy_j, 2),
        }


@dataclass
class _DeviceAgg:
    """Running per-device sums — what ``summarise`` needs, O(devices)."""
    n: int = 0
    is_master: bool = False
    download_ms: MS = 0.0
    transfer_ms: MS = 0.0
    return_ms: MS = 0.0
    processing_ms: MS = 0.0
    wait_ms: MS = 0.0
    overhead_ms: MS = 0.0
    turnaround_ms: MS = 0.0
    video_len_ms: MS = 0.0
    esd: float = 0.0
    frames_total: int = 0
    frames_processed: int = 0
    energy_j: float = 0.0
    ttft_ms: MS = 0.0              # sum over records with a measured TTFT
    ttft_n: int = 0

    def fold(self, r: SegmentRecord) -> None:
        self.n += 1
        self.is_master = self.is_master or r.is_master
        self.download_ms += r.download_ms
        self.transfer_ms += r.transfer_ms
        self.return_ms += r.return_ms
        self.processing_ms += r.processing_ms
        self.wait_ms += r.wait_ms
        self.overhead_ms += r.overhead_ms
        self.turnaround_ms += r.turnaround_ms
        self.video_len_ms += r.video_len_ms
        self.esd = max(self.esd, r.esd)
        self.frames_total += r.frames_total
        self.frames_processed += r.frames_processed
        self.energy_j += r.energy_j
        if r.ttft_ms > 0:
            self.ttft_ms += r.ttft_ms
            self.ttft_n += 1

    def merge(self, o: "_DeviceAgg") -> None:
        self.n += o.n
        self.is_master = self.is_master or o.is_master
        for f in ("download_ms", "transfer_ms", "return_ms",
                  "processing_ms", "wait_ms", "overhead_ms",
                  "turnaround_ms", "video_len_ms", "frames_total",
                  "frames_processed", "energy_j", "ttft_ms", "ttft_n"):
            setattr(self, f, getattr(self, f) + getattr(o, f))
        self.esd = max(self.esd, o.esd)


class Ledger:
    """Collects SegmentRecords; summarises per device like the paper tables.

    Two storage modes share one API:

      * default: every record is kept (``self.records``) — exact
        percentiles, per-record ``check()``, full drill-down;
      * ``aggregate=True``: rows are folded into O(devices) running sums
        + O(buckets) quantile sketches and then DISCARDED — the fleet-
        scale mode (city-scale fleets cannot hold O(frames) host rows).
        Conservation is checked per record at ``add()`` time instead of
        at ``check()`` time, and ``percentiles()`` answers from the
        sketches, within their ``rel_err`` relative-error bound.

    Both modes always feed the sketches, so ``sketch_percentiles()`` and
    cross-ledger ``merge_from()`` (per-replica ledgers -> one fleet view)
    work either way, and sketch-vs-exact parity is testable on the
    default mode (``tests/test_telemetry.py``).
    """

    #: metrics with a streaming quantile sketch (mirrors ``percentiles``)
    SKETCH_METRICS = ("turnaround_ms", "ttft_ms", "skip_rate")

    def __init__(self, *, aggregate: bool = False,
                 rel_err: float = 0.01) -> None:
        self.records: List[SegmentRecord] = []
        self.aggregate = aggregate
        self.rel_err = rel_err
        self.sketches: Dict[str, QuantileSketch] = {
            m: QuantileSketch(rel_err) for m in self.SKETCH_METRICS}
        self.totals: Dict[str, float] = {
            "records": 0, "turnaround_ms": 0.0, "energy_j": 0.0,
            "real_time": 0, "frames_total": 0, "frames_processed": 0,
            "ttft_records": 0}
        self._aggs: Dict[str, _DeviceAgg] = {}

    def __len__(self) -> int:
        return int(self.totals["records"])

    def add(self, rec: SegmentRecord) -> None:
        self.totals["records"] += 1
        self.totals["turnaround_ms"] += rec.turnaround_ms
        self.totals["energy_j"] += rec.energy_j
        self.totals["real_time"] += rec.real_time
        self.totals["frames_total"] += rec.frames_total
        self.totals["frames_processed"] += rec.frames_processed
        # clamp sketch inputs: a conservation-violating record (processed
        # outside [0, total] -> skip_rate outside [0, 1]) must still be
        # *accepted* here so check() can flag it with its proper message,
        # not die inside the nonnegative-only sketch
        self.sketches["turnaround_ms"].add(max(rec.turnaround_ms, 0.0))
        self.sketches["skip_rate"].add(min(max(rec.skip_rate, 0.0), 1.0))
        if rec.ttft_ms > 0:
            self.totals["ttft_records"] += 1
            self.sketches["ttft_ms"].add(rec.ttft_ms)
        self._aggs.setdefault(rec.device, _DeviceAgg()).fold(rec)
        if self.aggregate:
            # the row is about to be dropped — conservation checks run now
            errors = self._record_errors(rec)
            if errors:
                raise AssertionError(
                    "ledger conservation violated:\n  "
                    + "\n  ".join(errors))
        else:
            self.records.append(rec)

    @staticmethod
    def _record_errors(r: SegmentRecord) -> List[str]:
        errors = []
        if not 0 <= r.frames_processed <= r.frames_total:
            errors.append(
                f"{r.video_id}/{r.stream}@{r.device}: processed "
                f"{r.frames_processed} outside [0, {r.frames_total}]")
        if r.frames_gated is None and r.frames_dropped is None:
            return errors                     # no per-cause accounting
        gated = r.frames_gated or 0
        dropped = r.frames_dropped or 0
        ddl = r.frames_deadline_dropped or 0
        if r.frames_processed + gated + dropped != r.frames_total:
            errors.append(
                f"{r.video_id}/{r.stream}@{r.device}: "
                f"processed {r.frames_processed} + gated {gated} "
                f"+ dropped {dropped} != offered {r.frames_total}")
        if ddl > dropped:
            errors.append(
                f"{r.video_id}/{r.stream}@{r.device}: deadline-dropped "
                f"{ddl} exceeds dropped {dropped}")
        return errors

    def check(self) -> None:
        """Frame-conservation assertion over every record.

        For any record: 0 <= processed <= total.  For records carrying the
        explicit skip decomposition (the fleet engine's), every offered
        frame must be accounted exactly once:

            processed + gated + dropped == total
            deadline-dropped <= dropped

        Raises ``AssertionError`` naming every violating stream — this is
        the invariant that makes accounting drift in the serving path fail
        loudly instead of quietly skewing skip-rate tables.  (An
        ``aggregate=True`` ledger ran these checks per record at ``add``
        time; here its record list is empty and the loop is a no-op.)
        """
        errors = []
        for r in self.records:
            errors.extend(self._record_errors(r))
        if errors:
            raise AssertionError(
                "ledger conservation violated:\n  " + "\n  ".join(errors))

    # ------------------------------------------------------------------
    def by_device(self) -> Dict[str, List[SegmentRecord]]:
        out: Dict[str, List[SegmentRecord]] = {}
        for r in self.records:
            out.setdefault(r.device, []).append(r)
        return out

    def summarise(self, wall_s: Optional[float] = None) -> List[DeviceSummary]:
        """Per-device means, built from the running aggregates (identical
        in both storage modes).  ``wall_s``, when given, is the measured
        wall-clock duration of the whole run: average power is then the
        device's total energy over that wall time; otherwise it is the
        paper's per-video metric — energy per video over the video's own
        nominal length."""
        sums = []
        for dev, a in sorted(self._aggs.items()):
            n = a.n
            video_s = (a.video_len_ms / n) / 1000.0
            if wall_s is not None and wall_s > 0:
                power_mw = 1000.0 * a.energy_j / wall_s
            else:
                power_mw = 1000.0 * (a.energy_j / n) / max(video_s, 1e-9)
            sums.append(DeviceSummary(
                device=dev,
                is_master=a.is_master,
                n=n,
                download_ms=a.download_ms / n,
                transfer_ms=a.transfer_ms / n,
                return_ms=a.return_ms / n,
                processing_ms=a.processing_ms / n,
                wait_ms=a.wait_ms / n,
                overhead_ms=a.overhead_ms / n,
                turnaround_ms=a.turnaround_ms / n,
                esd=a.esd,
                skip_rate=((1 - a.frames_processed / a.frames_total)
                           if a.frames_total else 0.0),
                avg_power_mw=power_mw,
                energy_j=a.energy_j,
                ttft_ms=a.ttft_ms / a.ttft_n if a.ttft_n else 0.0,
            ))
        return sums

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)
                    ) -> Dict[str, float]:
        """Tail summaries over the collected records: ``p50/p95/p99`` (by
        default) of turnaround, TTFT and skip rate, keyed
        ``"<metric>_p<q>"``.  TTFT percentiles cover only the records
        whose producer measured a TTFT (token workloads); an empty ledger
        (or no TTFT producers) yields 0.0 — benches surface these rows
        straight into the ``BENCH_*.json`` snapshot.  An aggregate-mode
        ledger keeps no rows and answers from its sketches instead (same
        keys, within ``rel_err``)."""
        if self.aggregate:
            return self.sketch_percentiles(qs)
        series = {
            "turnaround_ms": [r.turnaround_ms for r in self.records],
            "ttft_ms": [r.ttft_ms for r in self.records if r.ttft_ms > 0],
            "skip_rate": [r.skip_rate for r in self.records],
        }
        out: Dict[str, float] = {}
        for metric, values in series.items():
            for q in qs:
                key = f"{metric}_p{q:g}"
                out[key] = percentile(values, q)
        return out

    def sketch_percentiles(self, qs: Sequence[float] = (50, 95, 99)
                           ) -> Dict[str, float]:
        """The sketch-backed twin of :meth:`percentiles` — same keys,
        O(buckets) memory, each value within the sketch's ``rel_err``
        relative-error bound of the exact rank statistic (property-tested
        against :meth:`percentiles` in ``tests/test_telemetry.py``)."""
        return {f"{metric}_p{q:g}": self.sketches[metric].quantile(q)
                for metric in self.SKETCH_METRICS for q in qs}

    def merge_from(self, other: "Ledger") -> "Ledger":
        """Fold another ledger (a replica's, a cell's) into this one:
        sketches merge loss-free, totals and device aggregates sum, and
        record rows concatenate when the source kept them.  This is the
        fleet roll-up path — N per-replica aggregate ledgers merge into
        one fleet ledger whose percentiles match a single global ledger
        within ``rel_err``.  Returns self for chaining."""
        for m in self.SKETCH_METRICS:
            self.sketches[m].merge(other.sketches[m])
        for k, v in other.totals.items():
            self.totals[k] = self.totals.get(k, 0) + v
        for dev, agg in other._aggs.items():
            self._aggs.setdefault(dev, _DeviceAgg()).merge(agg)
        self.records.extend(other.records)
        return self

    def real_time_fraction(self) -> float:
        if not self.totals["records"]:
            return 0.0
        return self.totals["real_time"] / self.totals["records"]

    def mean_turnaround_ms(self) -> float:
        if not self.totals["records"]:
            return 0.0
        return self.totals["turnaround_ms"] / self.totals["records"]

    # ------------------------------------------------------------------
    def table(self, wall_s: Optional[float] = None) -> str:
        rows = [s.row() for s in self.summarise(wall_s)]
        if not rows:
            return "(empty ledger)"
        cols = list(rows[0].keys())
        widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
        head = " | ".join(c.ljust(widths[c]) for c in cols)
        sep = "-+-".join("-" * widths[c] for c in cols)
        body = "\n".join(" | ".join(str(r[c]).ljust(widths[c]) for c in cols)
                         for r in rows)
        return f"{head}\n{sep}\n{body}"
