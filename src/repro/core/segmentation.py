"""Segmentation: split a stream into equal parts, merge results exactly.

The paper splits videos with FFmpeg's segment tool so >=3 devices analyse
concurrently, then ``mergeResults`` recombines per-segment JSON (§3.2.4).
Here a *video* is a frame-indexed array (or an LM token stream); splitting
is an index partition and merging re-bases the frame indices — the property
tests assert ``merge(process(split(v))) == process(v)`` exactly.

Applicability (DESIGN.md §6): splitting one stream across devices requires
frame-independence.  Frame-level models (the paper's detector/pose, and
attention LMs with chunked prefill) qualify; recurrent-state archs
(xlstm / recurrentgemma) do not — their streams pin to one worker group and
rely on early stopping only, which the scheduler enforces via
``splittable=False``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence


@dataclass(frozen=True)
class Segment:
    video_id: str
    index: int                    # segment ordinal within the video
    num_segments: int
    frame_start: int              # first source-frame index
    frame_count: int
    stream: str = "outer"         # outer | inner
    payload: Any = None           # frames array / token slice / None (sim)
    # False for recurrent-state archs whose streams must stay in order on
    # one worker (DESIGN.md §6 arch-applicability)
    splittable: bool = True
    # total frames of the parent video: the ESD deadline references the
    # *video* length, not the segment's (paper §4.2.2, Table 4.4 — segment
    # turnarounds are judged against the 1 s source video)
    video_frames: int = 0

    @property
    def parent_frames(self) -> int:
        return self.video_frames or self.frame_count

    @property
    def segment_id(self) -> str:
        return f"{self.video_id}_{self.index:03d}"


def split_counts(total: int, n: int) -> List[int]:
    """Equal split with remainder spread over the leading segments."""
    base, rem = divmod(total, n)
    return [base + (1 if i < rem else 0) for i in range(n)]


def split_video(video_id: str, total_frames: int, n: int, *,
                stream: str = "outer", payload=None) -> List[Segment]:
    if n <= 0:
        raise ValueError(f"num segments must be positive, got {n}")
    n = min(n, total_frames) or 1
    counts = split_counts(total_frames, n)
    segs = []
    start = 0
    for i, c in enumerate(counts):
        part = None
        if payload is not None:
            part = payload[start: start + c]
        segs.append(Segment(video_id, i, n, start, c, stream, part,
                            video_frames=total_frames))
        start += c
    return segs


@dataclass
class SegmentResult:
    segment: Segment
    frames: Dict[int, Any] = field(default_factory=dict)  # local idx -> result
    frames_processed: int = 0

    def rebased(self) -> Dict[int, Any]:
        return {self.segment.frame_start + i: r for i, r in self.frames.items()}


def merge_results(parts: Sequence[SegmentResult]) -> Dict[int, Any]:
    """Recombine per-segment results into video-global frame results.

    Validates coverage: all segments of the same video, disjoint ranges.
    """
    if not parts:
        return {}
    vid = parts[0].segment.video_id
    seen = set()
    merged: Dict[int, Any] = {}
    for p in sorted(parts, key=lambda p: p.segment.index):
        if p.segment.video_id != vid:
            raise ValueError(
                f"merge across videos: {p.segment.video_id} vs {vid}")
        if p.segment.index in seen:
            raise ValueError(f"duplicate segment {p.segment.index} of {vid}")
        seen.add(p.segment.index)
        merged.update(p.rebased())
    expect = set(range(parts[0].segment.num_segments))
    if seen != expect:
        raise ValueError(f"missing segments of {vid}: {sorted(expect - seen)}")
    return merged


def split_tokens(tokens, n: int) -> List[Any]:
    """Chunked-prefill split of an LM token stream (axis 0)."""
    counts = split_counts(len(tokens), n)
    out = []
    start = 0
    for c in counts:
        out.append(tokens[start: start + c])
        start += c
    return out
