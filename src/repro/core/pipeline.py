"""Simultaneous download + analysis: double-buffered ingest.

The paper's first optimisation overlaps downloading the next video pair with
analysing the current one.  In the event-clock runtime this overlap is
inherent (download times advance on the pair clock, device availability on
each device's own clock).  For *real* execution — the e2e example driving
actual JAX inference over synthetic dash-cam frames — this module provides
the host-side machinery:

  * :class:`DoubleBuffer` — a one-slot-lookahead prefetcher running the
    ingest callable on a background thread while the caller consumes the
    previous item (the paper's master download thread).
  * :func:`overlapped` — iterator adaptor: ``for item in overlapped(src)``
    guarantees ingest of item i+1 overlaps the loop body of item i.

On a pod the same pattern becomes host->device transfer overlap: the data
pipeline (``repro.data.prefetch``) calls ``jax.device_put`` inside the
background thread so dispatch of step i hides H2D of step i+1.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional, TypeVar

T = TypeVar("T")

_SENTINEL = object()


class DoubleBuffer:
    """One-producer one-consumer lookahead buffer (depth configurable)."""

    def __init__(self, source: Iterable[T], depth: int = 2,
                 transform: Optional[Callable[[T], T]] = None) -> None:
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._transform = transform
        self._err: Optional[BaseException] = None
        self._done = False
        self._thread = threading.Thread(
            target=self._produce, args=(iter(source),), daemon=True)
        self._thread.start()

    def _produce(self, it: Iterator[T]) -> None:
        try:
            for item in it:
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        except BaseException as e:          # surface in consumer
            self._err = e
        finally:
            self._q.put(_SENTINEL)

    def __iter__(self) -> Iterator[T]:
        return self

    def __next__(self) -> T:
        if self._done:
            # iterator protocol: stay exhausted instead of blocking on the
            # drained queue (the producer only enqueues the sentinel once)
            if self._err is not None:
                raise self._err
            raise StopIteration
        item = self._q.get()
        if item is _SENTINEL:
            self._done = True
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def overlapped(source: Iterable[T], depth: int = 2,
               transform: Optional[Callable[[T], T]] = None) -> Iterator[T]:
    """``for x in overlapped(gen())`` — ingest overlaps the loop body."""
    return iter(DoubleBuffer(source, depth=depth, transform=transform))
