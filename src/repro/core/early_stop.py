"""Early stopping (ESD) — the paper's deadline/straggler-mitigation policy.

The early-stop divisor (ESD) gives every video a processing deadline
``video_length / ESD``; frames not analysed by the deadline are discarded
(the *skip rate*).  The paper sets ESD manually per device (§3.2.3); its §6
future-work sketches dynamic adjustment — implemented here as an AIMD
controller (beyond-paper feature, benchmarked in ``benchmarks/esd_sweep``).

Host/XLA split (DESIGN.md assumption log): the paper stops a video mid-
analysis when a wall-clock timer fires; XLA programs are static, so the
budget is computed *before* dispatch from the EWMA per-frame cost and
applied as a static-shape frame mask (:func:`budget_mask`) — same policy,
control moved to the host loop, no recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass
class EarlyStopPolicy:
    """Static-ESD budget computation + skip accounting."""
    esd: float = 0.0                 # 0 or <=1 disables early stopping

    @property
    def enabled(self) -> bool:
        return self.esd > 1.0

    def deadline_ms(self, video_len_ms: float) -> Optional[float]:
        if not self.enabled:
            return None
        return video_len_ms / self.esd

    def frame_budget(self, video_len_ms: float, total_frames: int,
                     est_frame_cost_ms: float,
                     setup_ms: float = 0.0) -> int:
        """Frames affordable inside the deadline at the estimated cost.

        ``setup_ms`` is the per-file fixed cost (frame-extractor spin-up);
        it eats deadline without producing frames, which is why small
        granularities force high skip rates (paper §4.2.2).
        """
        if not self.enabled:
            return total_frames
        deadline = max(self.deadline_ms(video_len_ms) - setup_ms, 0.0)
        if est_frame_cost_ms <= 0:
            return total_frames
        return max(min(int(deadline // est_frame_cost_ms), total_frames), 0)


def budget_mask(total_frames: int, budget: jax.Array) -> jax.Array:
    """(total_frames,) float mask: 1.0 for frames inside the budget.

    ``budget`` is a traced int32 scalar — the mask keeps the dispatched
    program shape static while the *effective* work tracks the deadline.
    """
    return (jnp.arange(total_frames) < budget).astype(jnp.float32)


@dataclass
class DynamicESD:
    """AIMD controller for the ESD value (paper §6 future work).

    Tracks an EWMA of turnaround (the paper judges near-real-time on the
    per-device *average*, Tables 4.2-4.7) and applies:

    - smoothed turnaround > video length        -> additive increase
    - smoothed turnaround < length - hysteresis -> multiplicative decrease

    Smoothing + multiplicative decrease answer the paper's stability
    question ("the ESD may fluctuate wildly"): one slow download moves the
    EWMA, not the ESD, and recovery decays geometrically (§6 bullet 2).
    ``esd_max`` answers bullet 3: the value saturates instead of running
    away when real-time is unreachable.
    """
    esd: float = 1.0
    step: float = 0.25               # additive increase per deadline miss
    decay: float = 0.93              # multiplicative decrease factor
    hysteresis_ms: float = 40.0
    esd_min: float = 1.0
    esd_max: float = 8.0
    alpha: float = 0.25              # turnaround EWMA smoothing
    misses: int = 0
    adjustments: list = field(default_factory=list)
    _ewma: Optional[float] = None

    def update(self, turnaround_ms: float, video_len_ms: float) -> float:
        self._ewma = turnaround_ms if self._ewma is None else (
            self.alpha * turnaround_ms + (1 - self.alpha) * self._ewma)
        if self._ewma > video_len_ms:
            self.esd = min(self.esd + self.step, self.esd_max)
            self.misses += 1
        elif self._ewma < video_len_ms - self.hysteresis_ms:
            self.esd = max(self.esd * self.decay, self.esd_min)
        self.adjustments.append(self.esd)
        return self.esd

    def policy(self) -> EarlyStopPolicy:
        return EarlyStopPolicy(esd=self.esd if self.esd > 1.0 else 0.0)


@dataclass
class EWMA:
    """Exponentially-weighted estimate (per-frame cost, worker capacity)."""
    alpha: float = 0.3
    value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = x if self.value is None else (
            self.alpha * x + (1 - self.alpha) * self.value)
        return self.value

    def get(self, default: float) -> float:
        return self.value if self.value is not None else default
