"""The paper's published numbers (Tables 4.2-4.9), used for side-by-side
comparison in every benchmark.  Values are per-device averages in ms except
skip (fraction), esd (divisor) and power (mW)."""

# Table 4.2 — one-second one-node
T42 = {
    "pixel3":    dict(processing=385, wait=211, overhead=26, turnaround=972,
                      esd=2.8, skip=0.592, power=19.175),
    "pixel6":    dict(processing=389, wait=208, overhead=27, turnaround=974,
                      esd=2.6, skip=0.145, power=35.935),
    "oneplus8":  dict(processing=411, wait=166, overhead=20, turnaround=947,
                      esd=0.0, skip=0.0, power=110.208),
    "findx2pro": dict(processing=352, wait=150, overhead=22, turnaround=874,
                      esd=0.0, skip=0.0, power=172.817),
}

# Table 4.3 — one-second two-node (master*, worker) runs
T43 = [
    ("findx2pro*", "oneplus8",
     dict(master_turn=662, worker_turn=976, worker_esd=2.5, worker_skip=0.261)),
    ("findx2pro*", "pixel6",
     dict(master_turn=670, worker_turn=996, worker_esd=5.0, worker_skip=0.805)),
    ("pixel6*", "pixel3",
     dict(master_turn=831, worker_turn=981, worker_esd=6.0, worker_skip=0.987)),
]

# Table 4.4 — one-second three-node (findx2pro master, segmentation)
T44 = [
    ("findx2pro*", ("pixel6", "oneplus8"),
     dict(master_turn=655, worker_turns=(980, 891))),
    ("findx2pro*", ("pixel6", "pixel3"),
     dict(master_turn=652, worker_turns=(942, 922))),
]

# Table 4.5 — two-second one-node
T45 = {
    "pixel3":    dict(download=893, processing=766, turnaround=1952,
                      esd=2.7, skip=0.371),
    "pixel6":    dict(download=759, processing=783, turnaround=1925,
                      esd=0.0, skip=0.0),
    "oneplus8":  dict(download=598, processing=763, turnaround=1828,
                      esd=0.0, skip=0.0),
    "findx2pro": dict(download=613, processing=649, turnaround=1644,
                      esd=0.0, skip=0.0),
}

# Table 4.6 — two-second two-node
T46 = [
    ("findx2pro*", "oneplus8", dict(master_turn=1189, worker_turn=1836)),
    ("findx2pro*", "pixel6", dict(master_turn=1197, worker_turn=1901)),
    ("pixel6*", "pixel3", dict(master_turn=1637, worker_turn=1919)),
]

# Table 4.7 — two-second three-node, no early stopping anywhere
T47 = [
    ("findx2pro*", ("pixel6", "oneplus8"),
     dict(master_turn=1238, worker_turns=(1604, 1398))),
    ("findx2pro*", ("pixel6", "pixel3"),
     dict(master_turn=1210, worker_turns=(1605, 1660))),
]

# Tables 4.8/4.9 — per-video average power (mW), one-node rows
T48_POWER_1S = {"pixel3": 19.175, "pixel6": 35.935, "oneplus8": 110.208,
                "findx2pro": 172.817}
T49_POWER_2S = {"pixel3": 96.031, "pixel6": 57.537, "oneplus8": 217.600,
                "findx2pro": 353.838}
# battery % consumed per 1600 s run (Table 4.8, one-node)
T48_BATTERY = {"pixel3": 0.08, "pixel6": 0.05, "oneplus8": 0.05,
               "findx2pro": 0.05}
