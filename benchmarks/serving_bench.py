"""Serving bench: continuous-batching engine throughput + EDA policy effect.

CPU wall-clock on the reduced model — the relative numbers (batching gain,
priority-class latency split, deadline skip behaviour) are the deliverable;
absolute tokens/s is this host's.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.config import EDAConfig, get_arch
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

RNG = np.random.default_rng(0)


def _requests(cfg, n, max_new=8):
    return [Request(rid=f"{'outer' if i % 2 == 0 else 'inner'}-{i:02d}",
                    tokens=RNG.integers(0, cfg.vocab_size, 12),
                    max_new_tokens=max_new,
                    priority=0 if i % 2 == 0 else 1,
                    deadline_ms=0.0)
            for i in range(n)]


def batching_throughput(rows):
    print("\n== continuous batching: tokens/s vs slots ==")
    cfg = get_arch("starcoder2-3b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    for slots in (1, 2, 4):
        eng = ServeEngine(cfg, params, slots=slots, cache_capacity=64,
                          prefill_chunk=16)
        for r in _requests(cfg, 8):
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        print(f"slots={slots}: {toks / dt:7.1f} tok/s "
              f"mean_turn={np.mean([r.turnaround_ms for r in done]):7.1f} ms")
        rows.append((f"serve_slots{slots}", 1e6 * dt / max(toks, 1),
                     "us_per_token"))


def priority_latency_split(rows):
    print("\n== outer/inner priority classes ==")
    cfg = get_arch("starcoder2-3b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                      prefill_chunk=16)
    for r in _requests(cfg, 10, max_new=4):
        eng.submit(r)
    done = eng.run()
    for prio, label in ((0, "outer/hazard"), (1, "inner/distract")):
        ts = [r.ttft_ms for r in done if r.priority == prio]
        print(f"{label:16s} mean TTFT {np.mean(ts):8.1f} ms (n={len(ts)})")
        rows.append((f"serve_ttft_p{prio}", float(np.mean(ts)), label))


def deadline_skip(rows):
    print("\n== deadline token budgets (early stopping for serving) ==")
    cfg = get_arch("starcoder2-3b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    for esd in (0.0, 2.0, 4.0):
        eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                          prefill_chunk=16, eda=EDAConfig(esd=esd))
        eng.token_cost_ms.update(40.0)
        for r in _requests(cfg, 6, max_new=10):
            r.deadline_ms = 800.0
            eng.submit(r)
        done = eng.run()
        skip = np.mean([r.skip_rate for r in done])
        print(f"esd={esd:3.1f}: mean skip {100 * skip:5.1f}% "
              f"truncated {sum(r.truncated for r in done)}/{len(done)}")
        rows.append((f"serve_esd{esd}", float(skip), "skip_rate"))


def main(rows=None):
    rows = rows if rows is not None else []
    batching_throughput(rows)
    priority_latency_split(rows)
    deadline_skip(rows)
    return rows


if __name__ == "__main__":
    main()
