"""Scenario soak: the full scenario library + the 2k-tick churn soak.

Runs every scenario in ``repro.simulate.SCENARIOS`` at full length
against the real fleet stack on virtual clocks, checks every invariant
(ledger conservation, capacity bounds, placement, outer-priority bound,
gate-state travel, zero post-warmup recompiles, metrics conservation),
certifies determinism by double-running the golden scenario — the second
run with the full observability plane attached, so the certificate also
proves obs-neutrality (tracing/metrics never perturb a digest) — and
prints one row per scenario.

    PYTHONPATH=src python -m benchmarks.scenario_soak [--skip-soak]
        [--obs-dir DIR]

``--obs-dir`` writes the obs-enabled golden run's Perfetto trace
(``obs_trace.json``) and Prometheus exposition dump
(``obs_metrics.prom``) into DIR — uploaded as CI artifacts on failure.

Wall-clock here is host simulation speed, not serving performance — the
deliverables are the invariant verdicts, the virtual-tick volume, and
the per-seed digests (any of which changing is a behavioural diff).
"""
from __future__ import annotations

import argparse
import os
import time

from repro.obs import MetricsRegistry, SpanTracer
from repro.simulate import SCENARIOS, get_scenario, run_scenario


def main(rows=None, skip_soak: bool = False, digests_path: str = "",
         obs_dir: str = ""):
    rows = rows if rows is not None else []
    total_ticks = 0
    total_violations = 0
    names = [n for n in sorted(SCENARIOS)
             if not (skip_soak and n == "soak_churn")]
    digests = []
    print(f"{'scenario':22s} {'ticks':>6s} {'wall_s':>7s} {'joined':>6s} "
          f"{'off':>7s} {'adm':>7s} {'gate':>6s} {'ddl':>6s} "
          f"{'rebind':>6s} {'viol':>4s}  digest")
    for name in names:
        t0 = time.time()
        res = run_scenario(get_scenario(name))
        wall = time.time() - t0
        s = res.summary
        total_ticks += s["ticks"]
        total_violations += s["violations"]
        digests.append(f"{name} seed={s['seed']} {res.digest}")
        print(f"{name:22s} {s['ticks']:6d} {wall:7.1f} {s['joined']:6d} "
              f"{s['off']:7d} {s['adm']:7d} {s['gate']:6d} {s['ddl']:6d} "
              f"{s['rebinds']:6d} {s['violations']:4d}  "
              f"{res.digest[:12]}")
        for v in res.violations:
            print(f"    !! {v}")
    if digests_path:
        # per-seed digests survive as a CI artifact: diffing two runs'
        # digest files localises *which* scenario drifted
        with open(digests_path, "w") as f:
            f.write("\n".join(digests) + "\n")

    # determinism certificate: the golden scenario twice — the second run
    # with the full obs plane on, so one digest equality certifies both
    # run-to-run determinism AND obs-neutrality
    a = run_scenario(get_scenario("golden_churn"))
    metrics, tracer = MetricsRegistry(), SpanTracer()
    b = run_scenario(get_scenario("golden_churn"),
                     metrics=metrics, tracer=tracer)
    det = a.digest == b.digest
    if obs_dir:
        os.makedirs(obs_dir, exist_ok=True)
        tracer.dump(os.path.join(obs_dir, "obs_trace.json"))
        with open(os.path.join(obs_dir, "obs_metrics.prom"), "w") as f:
            f.write(metrics.expose())
        print(f"[wrote obs_trace.json ({len(tracer)} events) and "
              f"obs_metrics.prom ({len(metrics)} metrics) to {obs_dir}]")
    print(f"\nvirtual ticks simulated: {total_ticks}   "
          f"invariant violations: {total_violations}   "
          f"determinism (golden twice, 2nd run obs-on): "
          f"{'OK' if det else 'MISMATCH'}")
    rows.append(("scenario_soak_ticks", total_ticks, "virtual_ticks"))
    rows.append(("scenario_soak_violations", total_violations, "count"))
    rows.append(("scenario_soak_deterministic", float(det), "1=identical"))
    assert det, ("golden scenario trace diverged between identical runs "
                 "(second run had the obs plane attached)")
    assert total_violations == 0, f"{total_violations} invariant violations"
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-soak", action="store_true",
                    help="skip the 2000-tick soak_churn scenario")
    ap.add_argument("--digests", default="",
                    help="write per-scenario trace digests to this file "
                         "(uploaded as a CI artifact on failure)")
    ap.add_argument("--obs-dir", default="",
                    help="write the obs-enabled golden run's Perfetto "
                         "trace + metrics exposition dump into this dir "
                         "(uploaded as CI artifacts on failure)")
    args = ap.parse_args()
    main(skip_soak=args.skip_soak, digests_path=args.digests,
         obs_dir=args.obs_dir)
