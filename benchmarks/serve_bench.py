"""Token-engine bench: decode throughput, chunked-prefill TTFT, EDA policy.

The ``ServeEngine`` path of the unified EngineCore — CPU wall-clock on the
reduced model, so the *relative* numbers (batching speedup, priority-class
TTFT split, deadline skip behaviour) are the deliverable and absolute
tokens/s is this host's.  Ledger percentile summaries (p50/p95/p99
turnaround, TTFT, skip rate) are surfaced as rows so they land in the
``BENCH_*.json`` snapshot.

Gated metrics (see ``GATE_RULES`` in ``benchmarks/run.py``):
``serve_batching_speedup`` is self-normalising and tightly toleranced;
``serve_decode_us_per_token`` / ``serve_ttft_*`` are absolute wall-clock
and only catch catastrophic slowdowns.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.config import EDAConfig, get_arch
from repro.core.telemetry import Ledger
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

RNG = np.random.default_rng(0)


def _setup(arch="starcoder2-3b"):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, jax.random.key(0))
    return cfg, params


def _requests(cfg, n, max_new=8, n_prompt=12):
    return [Request(rid=f"{'outer' if i % 2 == 0 else 'inner'}-{i:02d}",
                    tokens=RNG.integers(0, cfg.vocab_size, n_prompt),
                    max_new_tokens=max_new,
                    priority=0 if i % 2 == 0 else 1,
                    deadline_ms=0.0)
            for i in range(n)]


def decode_throughput(rows):
    print("\n== continuous batching: decode tokens/s vs slots ==")
    cfg, params = _setup()
    us_per_tok = {}
    for slots in (1, 2, 4):
        eng = ServeEngine(cfg, params, slots=slots, cache_capacity=64,
                          prefill_chunk=16)
        for r in _requests(cfg, 8):
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        us_per_tok[slots] = 1e6 * dt / max(toks, 1)
        print(f"slots={slots}: {toks / dt:7.1f} tok/s "
              f"mean_turn={np.mean([r.turnaround_ms for r in done]):7.1f} ms")
        rows.append((f"serve_decode_us_per_token_slots{slots}",
                     us_per_tok[slots], "us_per_token"))
    speedup = us_per_tok[1] / us_per_tok[4]
    print(f"batching speedup (slots 1 -> 4): {speedup:.2f}x")
    rows.append(("serve_batching_speedup", speedup, "x_vs_slots1"))


def prefill_ttft(rows):
    print("\n== chunked-prefill TTFT (long prompts through the ring) ==")
    cfg, params = _setup()
    ledger = Ledger()
    # chunk must stay inside the reduced arch's sliding window (8): the
    # 48-token prompts prefill as 6 ring-wrapping chunks per request
    eng = ServeEngine(cfg, params, slots=2, cache_capacity=128,
                      prefill_chunk=8, ledger=ledger)
    for r in _requests(cfg, 8, max_new=4, n_prompt=48):
        eng.submit(r)
    done = eng.run()
    ttfts = [r.ttft_ms for r in done]
    print(f"TTFT mean {np.mean(ttfts):8.1f} ms over {len(done)} requests "
          f"(48-token prompts, chunk=8)")
    pct = ledger.percentiles()
    for key in ("ttft_ms_p50", "ttft_ms_p95",
                "turnaround_ms_p50", "turnaround_ms_p95"):
        rows.append((f"serve_{key}", pct[key], "ledger_percentile"))


def priority_latency_split(rows):
    print("\n== outer/inner priority classes ==")
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                      prefill_chunk=16)
    for r in _requests(cfg, 10, max_new=4):
        eng.submit(r)
    done = eng.run()
    for prio, label in ((0, "outer/hazard"), (1, "inner/distract")):
        ts = [r.ttft_ms for r in done if r.priority == prio]
        print(f"{label:16s} mean TTFT {np.mean(ts):8.1f} ms (n={len(ts)})")
        rows.append((f"serve_ttft_class_p{prio}", float(np.mean(ts)), label))


def deadline_skip(rows):
    print("\n== deadline token budgets (early stopping for serving) ==")
    cfg, params = _setup()
    for esd in (0.0, 2.0, 4.0):
        eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                          prefill_chunk=16, eda=EDAConfig(esd=esd))
        eng.token_cost_ms.update(40.0)
        for r in _requests(cfg, 6, max_new=10):
            r.deadline_ms = 800.0
            eng.submit(r)
        done = eng.run()
        skip = np.mean([r.skip_rate for r in done])
        print(f"esd={esd:3.1f}: mean skip {100 * skip:5.1f}% "
              f"truncated {sum(r.truncated for r in done)}/{len(done)}")
        rows.append((f"serve_esd{esd}", float(skip), "skip_rate"))


def main(rows=None):
    rows = rows if rows is not None else []
    decode_throughput(rows)
    prefill_ttft(rows)
    priority_latency_split(rows)
    deadline_skip(rows)
    return rows


if __name__ == "__main__":
    main()
