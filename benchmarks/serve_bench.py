"""Token-engine bench: decode throughput, chunked-prefill TTFT, EDA policy.

The ``ServeEngine`` path of the unified EngineCore — CPU wall-clock on the
reduced model, so the *relative* numbers (batching speedup, priority-class
TTFT split, deadline skip behaviour) are the deliverable and absolute
tokens/s is this host's.  Ledger percentile summaries (p50/p95/p99
turnaround, TTFT, skip rate) are surfaced as rows so they land in the
``BENCH_*.json`` snapshot.

Timing methodology: every timed engine's geometry is warmed first on a
throwaway engine — the serving jits are module-level and shared by
``(cfg, opts, sample)`` (``serving.engine.get_jits``), so the warm-up
compiles every prefill-chunk width and the decode graph once and the
timed run measures steady-state serving, not XLA compilation.

``decode_throughput`` pins ``paged=False`` so the ``serve_decode_*`` /
``serve_batching_speedup`` series keeps measuring the contiguous layout
it always has; ``paged_steady`` measures the paged block-pool layout
beside it (``serve_paged_*``) plus a paged-vs-dense greedy-token parity
bit.

Gated metrics (see ``GATE_RULES`` in ``benchmarks/run.py``):
``serve_batching_speedup`` / ``serve_paged_batching_speedup`` are
self-normalising and tightly toleranced; ``serve_paged_token_parity`` is
exact; ``serve_decode_us_per_token`` / ``serve_paged_decode_us_per_token``
/ ``serve_ttft_*`` are absolute wall-clock and only catch catastrophic
slowdowns.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.config import EDAConfig, get_arch
from repro.core.clock import PREFILL, TICK, TOKEN, VirtualClock
from repro.core.telemetry import Ledger
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

RNG = np.random.default_rng(0)


def _setup(arch="starcoder2-3b"):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, jax.random.key(0))
    return cfg, params


def _requests(cfg, n, max_new=8, n_prompt=12, rng=None):
    rng = rng if rng is not None else RNG
    return [Request(rid=f"{'outer' if i % 2 == 0 else 'inner'}-{i:02d}",
                    tokens=rng.integers(0, cfg.vocab_size, n_prompt),
                    max_new_tokens=max_new,
                    priority=0 if i % 2 == 0 else 1,
                    deadline_ms=0.0)
            for i in range(n)]


def _warm(cfg, params, *, slots, cache_capacity, prefill_chunk, paged):
    """Compile this geometry's serving jits on a throwaway engine: a
    ``2 * prefill_chunk - 1`` prompt traces every power-of-two chunk
    width, the run traces the decode graph."""
    eng = ServeEngine(cfg, params, slots=slots, cache_capacity=cache_capacity,
                      prefill_chunk=prefill_chunk, paged=paged)
    n_prompt = min(2 * prefill_chunk - 1, cache_capacity - 1)
    rng = np.random.default_rng(1)
    for i in range(2):
        eng.submit(Request(rid=f"warm-{i}",
                           tokens=rng.integers(0, cfg.vocab_size, n_prompt),
                           max_new_tokens=2))
    eng.run()


def _timed_run(cfg, params, *, slots, paged, n_req=8):
    eng = ServeEngine(cfg, params, slots=slots, cache_capacity=64,
                      prefill_chunk=16, paged=paged)
    for r in _requests(cfg, n_req):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return done, toks, dt


def decode_throughput(rows):
    print("\n== continuous batching: decode tokens/s vs slots ==")
    cfg, params = _setup()
    us_per_tok = {}
    for slots in (1, 2, 4):
        _warm(cfg, params, slots=slots, cache_capacity=64, prefill_chunk=16,
              paged=False)
        done, toks, dt = _timed_run(cfg, params, slots=slots, paged=False)
        us_per_tok[slots] = 1e6 * dt / max(toks, 1)
        print(f"slots={slots}: {toks / dt:7.1f} tok/s "
              f"mean_turn={np.mean([r.turnaround_ms for r in done]):7.1f} ms")
        rows.append((f"serve_decode_us_per_token_slots{slots}",
                     us_per_tok[slots], "us_per_token"))
    speedup = us_per_tok[1] / us_per_tok[4]
    print(f"batching speedup (slots 1 -> 4): {speedup:.2f}x")
    rows.append(("serve_batching_speedup", speedup, "x_vs_slots1"))


def paged_steady(rows):
    """Paged block-pool layout beside the contiguous series: steady-state
    decode cost, batching speedup, and a paged-vs-dense greedy-token
    parity bit (prompts within the sliding window, where the contiguous
    ring is exact — see ``tests/test_paged_attention.py`` for why longer
    prompts use the full-model golden instead)."""
    print("\n== paged KV (block pool): steady-state decode + parity ==")
    cfg, params = _setup()
    us_per_tok = {}
    for slots in (1, 4):
        _warm(cfg, params, slots=slots, cache_capacity=64, prefill_chunk=16,
              paged=True)
        done, toks, dt = _timed_run(cfg, params, slots=slots, paged=True)
        us_per_tok[slots] = 1e6 * dt / max(toks, 1)
        print(f"slots={slots}: {toks / dt:7.1f} tok/s (paged)")
        rows.append((f"serve_paged_decode_us_per_token_slots{slots}",
                     us_per_tok[slots], "us_per_token"))
    speedup = us_per_tok[1] / us_per_tok[4]
    print(f"paged batching speedup (slots 1 -> 4): {speedup:.2f}x")
    rows.append(("serve_paged_batching_speedup", speedup, "x_vs_slots1"))

    window = cfg.window if cfg.attention == "sliding" else 0
    n_prompt = window if window else 12
    streams = {}
    for paged in (False, True):
        eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                          prefill_chunk=16, paged=paged)
        for r in _requests(cfg, 8, max_new=6, n_prompt=n_prompt,
                           rng=np.random.default_rng(2)):
            eng.submit(r)
        streams[paged] = {r.rid: tuple(r.generated) for r in eng.run()}
    same = [streams[True][rid] == streams[False][rid]
            for rid in streams[True]]
    parity = float(np.mean(same))
    print(f"paged-vs-dense greedy token parity: {parity:.3f} "
          f"({sum(same)}/{len(same)} identical streams)")
    rows.append(("serve_paged_token_parity", parity, "frac_identical"))


def prefill_ttft(rows):
    print("\n== chunked-prefill TTFT (long prompts through the ring) ==")
    cfg, params = _setup()
    ledger = Ledger()
    # chunk must stay inside the reduced arch's sliding window (8): the
    # 48-token prompts prefill as 6 ring-wrapping chunks per request
    _warm(cfg, params, slots=2, cache_capacity=128, prefill_chunk=8,
          paged=False)
    eng = ServeEngine(cfg, params, slots=2, cache_capacity=128,
                      prefill_chunk=8, ledger=ledger, paged=False)
    for r in _requests(cfg, 8, max_new=4, n_prompt=48):
        eng.submit(r)
    done = eng.run()
    ttfts = [r.ttft_ms for r in done]
    print(f"TTFT mean {np.mean(ttfts):8.1f} ms over {len(done)} requests "
          f"(48-token prompts, chunk=8)")
    pct = ledger.percentiles()
    for key in ("ttft_ms_p50", "ttft_ms_p95",
                "turnaround_ms_p50", "turnaround_ms_p95"):
        rows.append((f"serve_{key}", pct[key], "ledger_percentile"))


def priority_latency_split(rows):
    print("\n== outer/inner priority classes ==")
    cfg, params = _setup()
    _warm(cfg, params, slots=2, cache_capacity=64, prefill_chunk=16,
          paged=False)
    eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                      prefill_chunk=16, paged=False)
    for r in _requests(cfg, 10, max_new=4):
        eng.submit(r)
    done = eng.run()
    for prio, label in ((0, "outer/hazard"), (1, "inner/distract")):
        ts = [r.ttft_ms for r in done if r.priority == prio]
        print(f"{label:16s} mean TTFT {np.mean(ts):8.1f} ms (n={len(ts)})")
        rows.append((f"serve_ttft_class_p{prio}", float(np.mean(ts)), label))


def deadline_skip(rows):
    """Virtual-clocked: with the shared jits warm, a real token costs
    ~0.5 ms and an 800 ms deadline never binds — the virtual clock pins
    the per-token cost at 40 ms so the ESD knob's skip split is
    deterministic and machine-independent."""
    print("\n== deadline token budgets (early stopping for serving) ==")
    cfg, params = _setup()
    for esd in (0.0, 2.0, 4.0):
        clock = VirtualClock(rates={TOKEN: 0.040, PREFILL: 0.001,
                                    TICK: 0.0002})
        eng = ServeEngine(cfg, params, slots=2, cache_capacity=64,
                          prefill_chunk=16, eda=EDAConfig(esd=esd),
                          clock=clock, paged=False)
        eng.token_cost_ms.update(40.0)
        for r in _requests(cfg, 6, max_new=10):
            r.deadline_ms = 800.0
            eng.submit(r)
        done = eng.run()
        skip = np.mean([r.skip_rate for r in done])
        print(f"esd={esd:3.1f}: mean skip {100 * skip:5.1f}% "
              f"truncated {sum(r.truncated for r in done)}/{len(done)}")
        rows.append((f"serve_esd{esd}", float(skip), "skip_rate"))


def main(rows=None):
    rows = rows if rows is not None else []
    decode_throughput(rows)
    paged_steady(rows)
    prefill_ttft(rows)
    priority_latency_split(rows)
    deadline_skip(rows)
    return rows


if __name__ == "__main__":
    main()
