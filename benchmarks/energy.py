"""Energy benchmark: paper Tables 4.8/4.9 (per-video mW + battery %)."""
from __future__ import annotations

from dataclasses import replace

from repro.config import EDAConfig
from repro.core.energy import EnergyModel
from repro.core.runtime import EDARuntime, PAPER_DEVICES

from benchmarks import paper_tables as P

N_PAIRS = 300


def one_node_power(rows, gran, simdl, paper):
    label = f"{gran:.0f}s"
    print(f"\n== Table 4.{8 if gran == 1 else 9}: {label} one-node "
          f"per-video power, ours|paper mW ==")
    em = EnergyModel()
    for name, want in paper.items():
        dev = replace(PAPER_DEVICES[name], dynamic_esd=True)
        rt = EDARuntime(eda=EDAConfig(granularity_s=gran,
                                      simulate_download_s=simdl,
                                      dynamic_esd=True), master=dev)
        led = rt.run(N_PAIRS)
        s = led.summarise()[0]
        wall_s = N_PAIRS * gran
        batt = em.battery_pct(name, s.energy_j, wall_s)
        # scale to the paper's 1600 s of video for the battery comparison
        batt_1600 = batt * 1600 / (N_PAIRS * gran * 2)
        print(f"{name:10s} {s.avg_power_mw:7.1f}|{want:7.1f} mW   "
              f"battery/1600s {batt_1600:4.1f}|"
              f"{100 * P.T48_BATTERY[name]:4.1f}%")
        rows.append((f"energy_{label}_{name}", s.avg_power_mw,
                     f"paper={want}"))


def ordering_check(rows):
    """The load-bearing claim: flagship SoCs burn multiples of the Pixels."""
    power = {}
    for name in P.T48_POWER_1S:
        dev = replace(PAPER_DEVICES[name], dynamic_esd=True)
        rt = EDARuntime(eda=EDAConfig(granularity_s=1.0,
                                      simulate_download_s=0.35,
                                      dynamic_esd=True), master=dev)
        led = rt.run(100)
        power[name] = led.summarise()[0].avg_power_mw
    ok = (power["findx2pro"] > power["oneplus8"]
          > power["pixel6"] > 0 and power["oneplus8"] > 2 * power["pixel3"])
    print(f"\nenergy ordering findx2pro>oneplus8>>pixels: {ok}")
    rows.append(("energy_ordering", 1.0 if ok else 0.0, "paper=True"))


def main(rows=None):
    rows = rows if rows is not None else []
    one_node_power(rows, 1.0, 0.35, P.T48_POWER_1S)
    one_node_power(rows, 2.0, 0.0, P.T49_POWER_2S)
    ordering_check(rows)
    return rows


if __name__ == "__main__":
    main()
