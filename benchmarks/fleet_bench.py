"""Fleet bench: frames/s vs slots x streams x motion gating x ingest path.

Four measurements, all on the synthetic dash-cam clips:

  1. cross-stream batching — the same 8-stream workload through engines
     with 1/2/8 slots (gate off): slot-batched inference amortises dispatch
     and fills the accelerator, the acceptance bar is >=2x frames/s for
     slots=8 over slots=1;
  2. stream scaling — frames/s as concurrent streams grow at fixed slots;
  3. motion gating — a 3x-duplicated frame workload (a 30 fps cam over a
     10 fps scene) with the gate on vs off: gated near-duplicates never
     reach a batch slot, whole ticks with no admitted frame skip dispatch
     entirely, and the skip shows up as ledger skip-rate;
  4. ingest path — jnp 3-pass vs the fused Pallas ``kernels.vision_ops``
     ingest (interpret mode on CPU): certifies end-to-end admit/gate
     parity between the two implementations.

CPU wall-clock on tiny models: relative numbers are the deliverable.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.data import DashCamSource
from repro.streams import OUTER, VisionServeEngine

RES, INPUT_RES, FPS = 64, 32, 30


def _clips(n_streams: int, frames: int, repeat: int = 1) -> list:
    src = DashCamSource(granularity_s=frames / FPS, fps=FPS, res=RES, seed=5)
    clips = []
    for i in range(n_streams):
        outer = src.pair(i).outer
        clips.append(np.repeat(outer, repeat, axis=0)[:frames])
    return clips


def _one_drain(slots: int, clips: list, use_gate: bool,
               use_pallas: bool = False):
    eng = VisionServeEngine("bench", slots=slots, frame_res=RES,
                            input_res=INPUT_RES, fps=FPS,
                            use_gate=use_gate, use_pallas=use_pallas,
                            rng=jax.random.key(0))
    for i, clip in enumerate(clips):
        eng.open_stream(f"s{i:02d}", OUTER)
        for f in clip:
            eng.push(f"s{i:02d}", f)
    t0 = time.perf_counter()
    done = eng.drain()
    wall = time.perf_counter() - t0
    for i in range(len(clips)):
        eng.close_stream(f"s{i:02d}")
    return done, wall, eng


def _run(slots: int, clips: list, use_gate: bool, repeats: int = 3,
         use_pallas: bool = False):
    """Best-of-N drains (first is a compile warm-up and is discarded):
    the container CPU is noisy, min-wall is the standard stable estimator."""
    _one_drain(slots, clips, use_gate, use_pallas)  # warm compile caches
    best = None
    for _ in range(repeats):
        done, wall, eng = _one_drain(slots, clips, use_gate, use_pallas)
        if best is None or wall < best[1]:
            best = (done, wall, eng)
    return best


def batching_scaling(rows):
    print("\n== cross-stream batching: frames/s vs slots (8 streams) ==")
    clips = _clips(8, 48)
    offered = sum(len(c) for c in clips)
    fps_by_slots = {}
    for slots in (1, 2, 8):
        done, wall, eng = _run(slots, clips, use_gate=False)
        fps = offered / wall
        fps_by_slots[slots] = fps
        s = eng.stats()
        print(f"slots={slots}: {fps:8.1f} frames/s "
              f"({done}/{offered} processed, {wall * 1000:.0f} ms, "
              f"{s['frame_cost_ms']:.2f} ms/frame amortised, "
              f"{s['tick_cost_ms']:.2f} ms/tick)")
        rows.append((f"fleet_slots{slots}", 1e6 * wall / offered,
                     "us_per_frame"))
    speedup = fps_by_slots[8] / fps_by_slots[1]
    print(f"batching speedup (slots=8 vs slots=1): {speedup:.2f}x")
    rows.append(("fleet_batching_speedup", speedup, "x_vs_slots1"))


def stream_scaling(rows):
    print("\n== stream scaling at slots=8 ==")
    for n in (2, 4, 8):
        clips = _clips(n, 24)
        offered = sum(len(c) for c in clips)
        _, wall, _ = _run(8, clips, use_gate=False)
        print(f"streams={n}: {offered / wall:8.1f} frames/s")
        rows.append((f"fleet_streams{n}", offered / wall, "frames_per_s"))


def gating_effect(rows):
    print("\n== motion gating on a 3x-duplicated frame workload ==")
    clips = _clips(8, 48, repeat=3)
    offered = sum(len(c) for c in clips)
    stats = {}
    for use_gate in (False, True):
        done, wall, eng = _run(8, clips, use_gate=use_gate)
        ledger = eng.ledger
        skip = 1 - done / offered
        stats[use_gate] = (offered / wall, skip)
        label = "gate on " if use_gate else "gate off"
        print(f"{label}: {offered / wall:8.1f} offered-frames/s   "
              f"inferred {done}/{offered}   skip {skip:5.1%}   "
              f"mean turnaround {ledger.mean_turnaround_ms():.0f} ms")
        if use_gate:
            print(ledger.table())
    speedup = stats[True][0] / stats[False][0]
    print(f"gating speedup: {speedup:.2f}x   frames shed: {stats[True][1]:.1%}")
    rows.append(("fleet_gate_skip_rate", stats[True][1], "skip_rate"))
    rows.append(("fleet_gate_speedup", speedup, "x_vs_ungated"))


def ingest_path(rows):
    """Ingest-path column: jnp 3-pass vs fused Pallas ingest (gate on).

    On this CPU container the Pallas path runs in INTERPRET mode — its
    wall-clock is Python interpretation overhead, not a perf number (the
    structural win is modeled in benchmarks/kernel_micro.py; TPU compiles
    the same calls to Mosaic).  What this column certifies is end-to-end
    PARITY: both paths must process/gate exactly the same frames.
    """
    print("\n== ingest path: jnp 3-pass vs fused Pallas (gate on) ==")
    clips = _clips(4, 12, repeat=2)
    offered = sum(len(c) for c in clips)
    outcome = {}
    for use_pallas in (False, True):
        done, wall, eng = _run(4, clips, use_gate=True, repeats=1,
                               use_pallas=use_pallas)
        # streams are closed after the drain: read per-stream outcomes from
        # the ledger records they flushed
        outcome[use_pallas] = (
            done, sorted((r.video_id, r.frames_processed)
                         for r in eng.ledger.records))
        label = "pallas (interpret!)" if use_pallas else "jnp 3-pass       "
        print(f"{label}: {offered / wall:8.1f} offered-frames/s   "
              f"inferred {done}/{offered}")
        rows.append((f"fleet_ingest_{'pallas' if use_pallas else 'jnp'}_fps",
                     offered / wall, "offered_frames_per_s"))
    parity = outcome[False] == outcome[True]
    print(f"admit/gate parity across paths: {'OK' if parity else 'MISMATCH'}"
          f"   per-stream processed {outcome[True][1]}")
    rows.append(("fleet_ingest_parity", float(parity), "1=identical"))
    assert parity, f"ingest paths diverged: {outcome}"


def main(rows=None):
    rows = rows if rows is not None else []
    batching_scaling(rows)
    stream_scaling(rows)
    gating_effect(rows)
    ingest_path(rows)
    return rows


if __name__ == "__main__":
    main()
