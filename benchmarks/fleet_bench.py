"""Fleet bench: frames/s vs slots x streams x gating x ingest x parallel.

Six measurements, all on the synthetic dash-cam clips:

  1. cross-stream batching — the same 8-stream workload through engines
     with 1/2/8 slots (gate off): slot-batched inference amortises dispatch
     and fills the accelerator, the acceptance bar is >=2x frames/s for
     slots=8 over slots=1;
  2. stream scaling — frames/s as concurrent streams grow at fixed slots;
  3. motion gating — a 3x-duplicated frame workload (a 30 fps cam over a
     10 fps scene) with the gate on vs off: gated near-duplicates never
     reach a batch slot, whole ticks with no admitted frame skip dispatch
     entirely, and the skip shows up as ledger skip-rate;
  4. ingest path — jnp 3-pass vs the fused Pallas ``kernels.vision_ops``
     ingest (interpret mode on CPU): certifies end-to-end admit/gate
     parity between the two implementations;
  5. parallel fleet tick — serial per-replica stepping vs the fused
     one-dispatch tick (``streams.fleet_step``) at 4 replicas.  The CI
     gate runs it under ``XLA_FLAGS=--xla_force_host_platform_device_
     count=8``; auto mode keeps vmap there (forced CPU devices execute
     sequentially — shard_map is the accelerator-mesh path, certified
     bit-identical by tests/test_fleet_step.py).  Target: >=2x fleet
     throughput at 4 replicas, with per-stream admit parity;
  6. mixed-tier fleet — the same serial-vs-fused comparison on a fleet
     whose replicas advertise heterogeneous model tiers (base f32/32px,
     low f32/16px, frugal bf16/16px): the fused tick groups replicas by
     geometry, keeps the 1-dispatch-per-tick contract, and must stay
     admit/gate-identical to serial stepping.

CPU wall-clock on tiny models: relative numbers are the deliverable.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.data import DashCamSource
from repro.streams import OUTER, FleetGateway, VisionServeEngine
from repro.streams.fleet_step import resolve_mode

RES, INPUT_RES, FPS = 64, 32, 30


def _clips(n_streams: int, frames: int, repeat: int = 1) -> list:
    src = DashCamSource(granularity_s=frames / FPS, fps=FPS, res=RES, seed=5)
    clips = []
    for i in range(n_streams):
        outer = src.pair(i).outer
        clips.append(np.repeat(outer, repeat, axis=0)[:frames])
    return clips


def _one_drain(slots: int, clips: list, use_gate: bool,
               use_pallas: bool = False):
    eng = VisionServeEngine("bench", slots=slots, frame_res=RES,
                            input_res=INPUT_RES, fps=FPS,
                            use_gate=use_gate, use_pallas=use_pallas,
                            rng=jax.random.key(0))
    for i, clip in enumerate(clips):
        eng.open_stream(f"s{i:02d}", OUTER)
        for f in clip:
            eng.push(f"s{i:02d}", f)
    t0 = time.perf_counter()
    done = eng.drain()
    wall = time.perf_counter() - t0
    for i in range(len(clips)):
        eng.close_stream(f"s{i:02d}")
    return done, wall, eng


def _run(slots: int, clips: list, use_gate: bool, repeats: int = 3,
         use_pallas: bool = False):
    """Best-of-N drains (first is a compile warm-up and is discarded):
    the container CPU is noisy, min-wall is the standard stable estimator."""
    _one_drain(slots, clips, use_gate, use_pallas)  # warm compile caches
    best = None
    for _ in range(repeats):
        done, wall, eng = _one_drain(slots, clips, use_gate, use_pallas)
        if best is None or wall < best[1]:
            best = (done, wall, eng)
    return best


def batching_scaling(rows):
    print("\n== cross-stream batching: frames/s vs slots (8 streams) ==")
    clips = _clips(8, 48)
    offered = sum(len(c) for c in clips)
    fps_by_slots = {}
    for slots in (1, 2, 8):
        done, wall, eng = _run(slots, clips, use_gate=False)
        fps = offered / wall
        fps_by_slots[slots] = fps
        s = eng.stats()
        print(f"slots={slots}: {fps:8.1f} frames/s "
              f"({done}/{offered} processed, {wall * 1000:.0f} ms, "
              f"{s['frame_cost_ms']:.2f} ms/frame amortised, "
              f"{s['tick_cost_ms']:.2f} ms/tick)")
        rows.append((f"fleet_slots{slots}", 1e6 * wall / offered,
                     "us_per_frame"))
    speedup = fps_by_slots[8] / fps_by_slots[1]
    print(f"batching speedup (slots=8 vs slots=1): {speedup:.2f}x")
    rows.append(("fleet_batching_speedup", speedup, "x_vs_slots1"))


def stream_scaling(rows):
    print("\n== stream scaling at slots=8 ==")
    for n in (2, 4, 8):
        clips = _clips(n, 24)
        offered = sum(len(c) for c in clips)
        _, wall, _ = _run(8, clips, use_gate=False)
        print(f"streams={n}: {offered / wall:8.1f} frames/s")
        rows.append((f"fleet_streams{n}", offered / wall, "frames_per_s"))


def gating_effect(rows):
    print("\n== motion gating on a 3x-duplicated frame workload ==")
    clips = _clips(8, 48, repeat=3)
    offered = sum(len(c) for c in clips)
    stats = {}
    for use_gate in (False, True):
        done, wall, eng = _run(8, clips, use_gate=use_gate)
        ledger = eng.ledger
        skip = 1 - done / offered
        stats[use_gate] = (offered / wall, skip)
        label = "gate on " if use_gate else "gate off"
        print(f"{label}: {offered / wall:8.1f} offered-frames/s   "
              f"inferred {done}/{offered}   skip {skip:5.1%}   "
              f"mean turnaround {ledger.mean_turnaround_ms():.0f} ms")
        if use_gate:
            print(ledger.table())
    speedup = stats[True][0] / stats[False][0]
    print(f"gating speedup: {speedup:.2f}x   frames shed: {stats[True][1]:.1%}")
    rows.append(("fleet_gate_skip_rate", stats[True][1], "skip_rate"))
    rows.append(("fleet_gate_speedup", speedup, "x_vs_ungated"))


def ingest_path(rows):
    """Ingest-path column: jnp 3-pass vs fused Pallas ingest (gate on).

    On this CPU container the Pallas path runs in INTERPRET mode — its
    wall-clock is Python interpretation overhead, not a perf number (the
    structural win is modeled in benchmarks/kernel_micro.py; TPU compiles
    the same calls to Mosaic).  What this column certifies is end-to-end
    PARITY: both paths must process/gate exactly the same frames.
    """
    print("\n== ingest path: jnp 3-pass vs fused Pallas (gate on) ==")
    clips = _clips(4, 12, repeat=2)
    offered = sum(len(c) for c in clips)
    outcome = {}
    for use_pallas in (False, True):
        done, wall, eng = _run(4, clips, use_gate=True, repeats=1,
                               use_pallas=use_pallas)
        # streams are closed after the drain: read per-stream outcomes from
        # the ledger records they flushed
        outcome[use_pallas] = (
            done, sorted((r.video_id, r.frames_processed)
                         for r in eng.ledger.records))
        label = "pallas (interpret!)" if use_pallas else "jnp 3-pass       "
        print(f"{label}: {offered / wall:8.1f} offered-frames/s   "
              f"inferred {done}/{offered}")
        rows.append((f"fleet_ingest_{'pallas' if use_pallas else 'jnp'}_fps",
                     offered / wall, "offered_frames_per_s"))
    parity = outcome[False] == outcome[True]
    print(f"admit/gate parity across paths: {'OK' if parity else 'MISMATCH'}"
          f"   per-stream processed {outcome[True][1]}")
    rows.append(("fleet_ingest_parity", float(parity), "1=identical"))
    assert parity, f"ingest paths diverged: {outcome}"


def _fleet_drain(n_replicas: int, n_vehicles: int, frames: int,
                 parallel: bool, input_res: int = INPUT_RES,
                 metrics=None, tracer=None, events=None):
    """Drive a whole gateway (outer+inner pairs) and drain it once."""
    replicas = [VisionServeEngine(f"r{i}", slots=4, frame_res=RES,
                                  input_res=input_res, fps=FPS,
                                  use_gate=True, rng=jax.random.key(i))
                for i in range(n_replicas)]
    gw = FleetGateway(replicas, parallel=parallel,
                      metrics=metrics, tracer=tracer, events=events)
    src = DashCamSource(granularity_s=frames / FPS, fps=FPS, res=RES, seed=7)
    clips = [src.pair(v) for v in range(n_vehicles)]
    for v in range(n_vehicles):
        gw.join(f"v{v:02d}")
    for v, pair in enumerate(clips):
        for outer, inner in zip(pair.outer[:frames], pair.inner[:frames]):
            gw.push(f"v{v:02d}", outer, inner)
    t0 = time.perf_counter()
    done = gw.drain()
    wall = time.perf_counter() - t0
    outcome = []
    for v in range(n_vehicles):
        for rec in gw.leave(f"v{v:02d}"):
            outcome.append((rec.video_id, rec.stream, rec.frames_processed,
                            rec.frames_gated))
    return done, wall, sorted(outcome)


def parallel_fleet(rows, repeats: int = 3):
    """Serial per-replica stepping vs the fused mesh-parallel fleet tick.

    Admit decisions do not depend on wall time (gate thresholds adapt on
    counts, deadline is off here), so the two paths must process/gate
    exactly the same frames — the parity column certifies it while the
    speedup column captures the dispatch/sync-amortisation win: serial
    stepping pays ~10 device dispatches + 4 host syncs per replica per
    tick, the fused tick pays one of each for the whole fleet.  Models
    run at MoveNet-Lightning-class edge resolution (input_res=16) — the
    regime the paper serves — where serving overhead, not conv FLOPs, is
    the scaling story (section 1 covers the conv-bound regime).  On a
    forced-host-device CPU mesh the auto mode stays vmap (CPU devices
    execute sequentially; see ``fleet_step.resolve_mode``), so the >=2x
    bar must clear WITHOUT fake-device parallelism.
    """
    n_rep, n_veh, frames, ires = 4, 8, 24, 16
    mode = resolve_mode(n_rep)
    print(f"\n== parallel fleet tick at {n_rep} replicas "
          f"({len(jax.devices())} devices -> mode={mode}) ==")
    offered = n_veh * 2 * frames
    stats = {}
    for parallel in (False, True):
        _fleet_drain(n_rep, n_veh, frames, parallel, ires)  # warm compile
        best = None
        for _ in range(repeats):
            done, wall, outcome = _fleet_drain(n_rep, n_veh, frames,
                                               parallel, ires)
            if best is None or wall < best[1]:
                best = (done, wall, outcome)
        stats[parallel] = best
        label = f"parallel ({mode})" if parallel else "serial          "
        print(f"{label}: {offered / best[1]:8.1f} offered-frames/s   "
              f"inferred {best[0]}/{offered}   {best[1] * 1000:.0f} ms")
        rows.append((f"fleet_{'parallel' if parallel else 'serial'}_fps",
                     offered / best[1], "offered_frames_per_s"))
    speedup = stats[False][1] / stats[True][1]
    parity = (stats[False][0] == stats[True][0]
              and stats[False][2] == stats[True][2])
    print(f"parallel speedup: {speedup:.2f}x   per-stream parity: "
          f"{'OK' if parity else 'MISMATCH'}")
    rows.append(("fleet_parallel_speedup", speedup, "x_vs_serial"))
    rows.append(("fleet_parallel_parity", float(parity), "1=identical"))
    assert parity, (
        f"serial/parallel outcomes diverged: {stats[False]} {stats[True]}")


def _mixed_tier_drain(n_vehicles: int, frames: int, parallel: bool):
    """A heterogeneous-tier gateway drain: base/low/frugal replicas in
    one fleet (three distinct resolution x dtype geometries)."""
    tiers = ("base", "low", "low", "frugal")
    replicas = [VisionServeEngine(f"r{i}", slots=4, frame_res=RES, fps=FPS,
                                  use_gate=True, tier=t,
                                  rng=jax.random.key(i))
                for i, t in enumerate(tiers)]
    gw = FleetGateway(replicas, parallel=parallel)
    src = DashCamSource(granularity_s=frames / FPS, fps=FPS, res=RES,
                        seed=11)
    clips = [src.pair(v) for v in range(n_vehicles)]
    for v in range(n_vehicles):
        gw.join(f"v{v:02d}")
    for v, pair in enumerate(clips):
        for outer, inner in zip(pair.outer[:frames], pair.inner[:frames]):
            gw.push(f"v{v:02d}", outer, inner)
    t0 = time.perf_counter()
    done = gw.drain()
    wall = time.perf_counter() - t0
    outcome = []
    for v in range(n_vehicles):
        for rec in gw.leave(f"v{v:02d}"):
            outcome.append((rec.video_id, rec.stream, rec.frames_processed,
                            rec.frames_gated))
    return done, wall, sorted(outcome)


def mixed_tier_fleet(rows, repeats: int = 3):
    """Mixed-tier fleet drain: serial vs the fused tick on a fleet whose
    replicas advertise three different tiers (base f32/32px, low f32/16px,
    frugal bf16/16px).  The fused tick groups replicas by geometry and
    still issues ONE device dispatch per tick; the parity column
    certifies per-stream admit/gate decisions are identical across the
    serial and grouped-parallel paths — including across the bf16 tier.
    """
    n_veh, frames = 8, 24
    mode = resolve_mode(4)
    print(f"\n== mixed-tier fleet (base/low/frugal) serial vs fused "
          f"({mode}) ==")
    offered = n_veh * 2 * frames
    stats = {}
    for parallel in (False, True):
        _mixed_tier_drain(n_veh, frames, parallel)      # warm compile
        best = None
        for _ in range(repeats):
            done, wall, outcome = _mixed_tier_drain(n_veh, frames,
                                                    parallel)
            if best is None or wall < best[1]:
                best = (done, wall, outcome)
        stats[parallel] = best
        label = "fused (grouped)" if parallel else "serial         "
        print(f"{label}: {offered / best[1]:8.1f} offered-frames/s   "
              f"inferred {best[0]}/{offered}   {best[1] * 1000:.0f} ms")
    parity = (stats[False][0] == stats[True][0]
              and stats[False][2] == stats[True][2])
    fps = offered / stats[True][1]
    print(f"mixed-tier parity: {'OK' if parity else 'MISMATCH'}")
    rows.append(("fleet_mixed_tier_fps", fps, "offered_frames_per_s"))
    rows.append(("fleet_mixed_tier_parity", float(parity), "1=identical"))
    assert parity, (
        f"mixed-tier serial/fused outcomes diverged: "
        f"{stats[False]} {stats[True]}")


def obs_overhead(rows, repeats: int = 3):
    """Observability overhead: the same gateway drain with the obs plane
    fully on (shared MetricsRegistry + unsampled SpanTracer) vs fully off
    (the NULL_TRACER / no-registry default).

    Two columns: the wall-clock ratio on/off (the gate tolerates noise —
    this is a tiny CPU workload where Python dict updates are a visible
    fraction of a tick; the contract is "obs must not multiply tick
    cost"), and a hard parity bit — per-stream processed/gated outcomes
    must be IDENTICAL with obs on, because the plane is observe-only by
    construction (pure clock reads, no charges).

    Set ``OBS_DUMP_DIR`` to write the last obs-on run's Perfetto trace
    (``bench_obs_trace.json``) and exposition (``bench_obs_metrics.prom``)
    into that directory — the bench-gate CI job uploads them on failure.
    """
    from repro.obs import MetricsRegistry, SpanTracer
    n_rep, n_veh, frames = 2, 4, 24
    print("\n== observability overhead: obs on vs off (gateway drain) ==")
    offered = n_veh * 2 * frames
    stats = {}
    last_obs = {}
    for obs_on in (False, True):
        _fleet_drain(n_rep, n_veh, frames, False)       # warm compile
        best = None
        for _ in range(repeats):
            kw = (dict(metrics=MetricsRegistry(), tracer=SpanTracer())
                  if obs_on else {})
            done, wall, outcome = _fleet_drain(n_rep, n_veh, frames,
                                               False, **kw)
            if best is None or wall < best[1]:
                best = (done, wall, outcome)
            if obs_on:
                last_obs = kw
        stats[obs_on] = best
    dump_dir = os.environ.get("OBS_DUMP_DIR", "")
    if dump_dir and last_obs:
        os.makedirs(dump_dir, exist_ok=True)
        last_obs["tracer"].dump(
            os.path.join(dump_dir, "bench_obs_trace.json"))
        with open(os.path.join(dump_dir, "bench_obs_metrics.prom"),
                  "w") as f:
            f.write(last_obs["metrics"].expose())
        print(f"[wrote bench obs trace + exposition to {dump_dir}]")
        label = "obs on " if obs_on else "obs off"
        print(f"{label}: {offered / best[1]:8.1f} offered-frames/s   "
              f"inferred {best[0]}/{offered}   {best[1] * 1000:.0f} ms")
    ratio = stats[True][1] / stats[False][1]
    parity = (stats[False][0] == stats[True][0]
              and stats[False][2] == stats[True][2])
    print(f"obs overhead: {ratio:.2f}x wall   outcome parity: "
          f"{'OK' if parity else 'MISMATCH'}")
    rows.append(("fleet_obs_overhead", ratio, "x_vs_obs_off"))
    rows.append(("fleet_obs_parity", float(parity), "1=identical"))
    assert parity, (
        f"obs-on outcomes diverged: {stats[False]} {stats[True]}")


def event_plane(rows, repeats: int = 3):
    """Event/alert plane: drain overhead, outcome parity, spool drain.

    Three columns.  The wall-clock ratio of a gateway drain with the
    event plane attached (typed envelopes, cooldown bookkeeping,
    evidence-ring pushes, the per-tick pump) vs without — the plane
    rides the host phases and must never multiply tick cost, so the
    gate is the same generous-ratio shape as fleet_obs_overhead.  A
    hard parity bit — per-stream processed/gated outcomes must be
    IDENTICAL with the plane on, because emission hooks only observe.
    And a spool-drain rate: buffer a burst behind a partitioned uplink,
    reconnect, and flush through the idempotent sink — the
    at-least-once recovery path whose throughput bounds how fast a
    returning vehicle catches the receiver up.
    """
    from repro.events import DedupSink, EventConfig, EventPlane, HAZARD
    n_rep, n_veh, frames = 2, 4, 24
    print("\n== event plane: overhead / parity / spool drain ==")
    offered = n_veh * 2 * frames
    stats = {}
    last_plane = None
    for ev_on in (False, True):
        _fleet_drain(n_rep, n_veh, frames, False)       # warm compile
        best = None
        for _ in range(repeats):
            plane = EventPlane(EventConfig(), DedupSink()) if ev_on \
                else None
            done, wall, outcome = _fleet_drain(n_rep, n_veh, frames,
                                               False, events=plane)
            if best is None or wall < best[1]:
                best = (done, wall, outcome)
                if ev_on:
                    last_plane = plane
        stats[ev_on] = best
        label = "events on " if ev_on else "events off"
        print(f"{label}: {offered / best[1]:8.1f} offered-frames/s   "
              f"inferred {best[0]}/{offered}   {best[1] * 1000:.0f} ms")
    ratio = stats[True][1] / stats[False][1]
    parity = (stats[False][0] == stats[True][0]
              and stats[False][2] == stats[True][2])
    print(f"event overhead: {ratio:.2f}x wall   outcome parity: "
          f"{'OK' if parity else 'MISMATCH'}   "
          f"emitted {last_plane.emitted}   "
          f"accepted {last_plane.sink.accepted_count}")
    rows.append(("fleet_event_overhead", ratio, "x_vs_events_off"))
    rows.append(("fleet_event_parity", float(parity), "1=identical"))
    assert parity, (
        f"event-plane-on outcomes diverged: {stats[False]} {stats[True]}")

    # spool drain: a partitioned vehicle accumulates a burst, then the
    # uplink returns and the whole backlog flushes through the dedup sink
    n_events = 512
    plane = EventPlane(EventConfig(cooldown_frames=0, evidence_frames=0,
                                   spool_cap=n_events + 8), DedupSink())
    em = plane.new_emitter("bench")
    plane.partition("v00")
    for i in range(n_events):
        em.emit("v00/outer", HAZARD, i, emit_s=float(i), score=0.9)
    assert plane.depth() == n_events
    t0 = time.perf_counter()
    plane.reconnect("v00")
    left = plane.flush()
    wall = time.perf_counter() - t0
    eps = n_events / wall
    print(f"spool drain: {n_events} events in {wall * 1000:.1f} ms "
          f"({eps:10.0f} events/s)   accepted "
          f"{plane.sink.accepted_count}   left {left}")
    rows.append(("fleet_event_drain_eps", eps, "events_per_s"))
    assert left == 0 and plane.sink.accepted_count == n_events, (
        f"spool drain lost events: {left} left, "
        f"{plane.sink.accepted_count}/{n_events} accepted")


def _region_drain(n_cells: int, per_cell: int, n_vehicles: int,
                  frames: int, parallel: bool):
    """Drive a hierarchical cell/region gateway and drain it once."""
    from repro.core.telemetry import Ledger
    from repro.streams.cells import CellGateway, RegionGateway
    cells = []
    for c in range(n_cells):
        replicas = [VisionServeEngine(f"c{c}r{i}", slots=4, frame_res=RES,
                                      input_res=16, fps=FPS, use_gate=True,
                                      rng=jax.random.key(8 * c + i))
                    for i in range(per_cell)]
        cells.append(CellGateway(f"cell{c}", replicas, overcommit=2.0,
                                 ledger=Ledger(aggregate=True),
                                 parallel=parallel))
    gw = RegionGateway(cells)
    src = DashCamSource(granularity_s=frames / FPS, fps=FPS, res=RES,
                        seed=13)
    clips = [src.pair(v) for v in range(n_vehicles)]
    for v in range(n_vehicles):
        gw.join(f"v{v:03d}")
    for v, pair in enumerate(clips):
        for outer, inner in zip(pair.outer[:frames], pair.inner[:frames]):
            gw.push(f"v{v:03d}", outer, inner)
    t0 = time.perf_counter()
    done = gw.drain()
    wall = time.perf_counter() - t0
    outcome = []
    for v in range(n_vehicles):
        for rec in gw.leave(f"v{v:03d}"):
            outcome.append((rec.video_id, rec.stream, rec.frames_processed,
                            rec.frames_gated))
    rollup = gw.rollup()
    rollup.check()
    return done, wall, sorted(outcome)


def fleet_scale(rows, repeats: int = 2):
    """Hierarchical control plane: bounded host time per frame at scale.

    Two columns.  ``fleet_host_us_per_frame_{8,64}r`` drains the same
    per-vehicle workload through one 8-replica cell vs 8 cells x 8
    replicas with 8x the vehicles (the ``streams.cells`` region path,
    fused cell ticks) and reports wall us per offered frame — the
    sublinearity bar is **64r <= 2x 8r**: if any per-tick host path were
    still O(fleet) instead of O(cell), the 8x-fleet figure would blow
    straight past it.  ``fleet_scale_parity`` runs a shrunk ``city_scale``
    scenario (4 cells x 2 replicas, scripted replica failure forcing
    cross-cell handoffs) serial vs mesh-parallel and demands identical
    golden-trace digests with zero invariant violations — the hierarchy
    must not fork the digest contract the flat gateway certifies.
    """
    print("\n== hierarchical fleet scale: host us/frame, 8r vs 64r ==")
    frames = 6
    shapes = {8: (1, 8, 16), 64: (8, 8, 128)}
    us = {}
    for n_rep, (n_cells, per_cell, n_veh) in shapes.items():
        offered = n_veh * 2 * frames
        _region_drain(n_cells, per_cell, n_veh, frames, True)  # warm
        best = None
        for _ in range(repeats):
            done, wall, _ = _region_drain(n_cells, per_cell, n_veh,
                                          frames, True)
            if best is None or wall < best[1]:
                best = (done, wall)
        us[n_rep] = 1e6 * best[1] / offered
        print(f"{n_rep:2d} replicas ({n_cells} cells x {per_cell}): "
              f"{us[n_rep]:8.1f} us/offered-frame   "
              f"inferred {best[0]}/{offered}   {best[1] * 1000:.0f} ms")
        rows.append((f"fleet_host_us_per_frame_{n_rep}r", us[n_rep],
                     "us_per_offered_frame"))
    ratio = us[64] / us[8]
    print(f"scale ratio (64r / 8r per-frame host time): {ratio:.2f}x "
          f"(bar: <= 2.0x)")
    assert ratio <= 2.0, (
        f"per-frame host time grew {ratio:.2f}x from 8 to 64 replicas — "
        f"an O(fleet) host path is back")

    from repro.simulate import get_scenario, run_scenario
    from repro.simulate.scenario import ScriptedEvent, city_replicas
    s = get_scenario(
        "city_scale",
        replicas=city_replicas(cells=4, per_cell=2, slots=4),
        initial_vehicles=40, max_vehicles=60, ticks=12,
        scripted=(ScriptedEvent(3, "fail_replica", "c0r0"),
                  ScriptedEvent(9, "restore_replica", "c0r0")))
    ser = run_scenario(s)
    par = run_scenario(s, parallel=True)
    parity = (not ser.violations and not par.violations
              and ser.digest == par.digest)
    print(f"cell-granular serial vs parallel digest parity: "
          f"{'OK' if parity else 'MISMATCH'} ({ser.digest[:12]})")
    rows.append(("fleet_scale_parity", float(parity), "1=identical"))
    assert parity, (
        f"hierarchical digests diverged: serial {ser.digest} "
        f"parallel {par.digest} violations {ser.violations} "
        f"{par.violations}")


def main(rows=None):
    rows = rows if rows is not None else []
    batching_scaling(rows)
    stream_scaling(rows)
    gating_effect(rows)
    ingest_path(rows)
    parallel_fleet(rows)
    mixed_tier_fleet(rows)
    fleet_scale(rows)
    obs_overhead(rows)
    event_plane(rows)
    return rows


if __name__ == "__main__":
    main()
