"""ESD sweep (§4.2.2) + dynamic-ESD controller (beyond paper, their §6).

Static sweep: turnaround/skip vs fixed ESD on the weakest device — shows the
deadline/accuracy trade the paper tunes by hand.  Dynamic: the AIMD
controller discovering the ESD online, including recovery after a simulated
congestion burst (the paper's open stability question).
"""
from __future__ import annotations

from dataclasses import replace

from repro.config import EDAConfig
from repro.core.early_stop import DynamicESD
from repro.core.runtime import EDARuntime, PAPER_DEVICES


def static_sweep(rows):
    print("\n== static ESD sweep (pixel3, 1 s) ==")
    print(f"{'esd':>4s} {'turnaround':>10s} {'skip %':>7s} {'real-time %':>11s}")
    for esd in (0.0, 1.5, 2.0, 2.5, 3.0, 4.0, 6.0):
        dev = replace(PAPER_DEVICES["pixel3"], esd=esd, dynamic_esd=False)
        rt = EDARuntime(eda=EDAConfig(granularity_s=1.0,
                                      simulate_download_s=0.35),
                        master=dev)
        led = rt.run(200)
        s = led.summarise()[0]
        print(f"{esd:4.1f} {s.turnaround_ms:10.0f} {100 * s.skip_rate:7.1f} "
              f"{100 * led.real_time_fraction():11.1f}")
        rows.append((f"esd_static_{esd}", s.turnaround_ms,
                     f"skip={s.skip_rate:.3f}"))


def dynamic_convergence(rows):
    print("\n== dynamic ESD: convergence + congestion recovery ==")
    ctl = DynamicESD(esd=1.0, step=0.25)
    # phase 1: sustainable load (turnaround 900ms for 1000ms videos)
    for _ in range(60):
        ctl.update(900.0, 1000.0)
    calm = ctl.esd
    # phase 2: congestion burst (2x slowdown)
    for _ in range(40):
        ctl.update(1800.0, 1000.0)
    burst = ctl.esd
    # phase 3: recovery
    for _ in range(120):
        ctl.update(700.0, 1000.0)
    rec = ctl.esd
    print(f"calm esd={calm:.2f} -> burst esd={burst:.2f} -> "
          f"recovered esd={rec:.2f} (bounded by esd_max={ctl.esd_max})")
    rows.append(("esd_dynamic_burst", burst, f"calm={calm:.2f},rec={rec:.2f}"))
    assert burst > calm and rec < burst


def granularity_effect(rows):
    print("\n== granularity effect on skip rate (paper's 1 s vs 2 s) ==")
    for name in ("pixel3", "pixel6"):
        skips = []
        for gran, simdl in ((1.0, 0.35), (2.0, 0.0)):
            dev = replace(PAPER_DEVICES[name], dynamic_esd=True)
            rt = EDARuntime(eda=EDAConfig(granularity_s=gran,
                                          simulate_download_s=simdl,
                                          dynamic_esd=True), master=dev)
            led = rt.run(200)
            skips.append(led.summarise()[0].skip_rate)
        print(f"{name}: skip 1s={100 * skips[0]:.1f}% -> 2s="
              f"{100 * skips[1]:.1f}%")
        rows.append((f"esd_gran_{name}", skips[1],
                     f"skip1s={skips[0]:.3f}"))


def main(rows=None):
    rows = rows if rows is not None else []
    static_sweep(rows)
    dynamic_convergence(rows)
    granularity_effect(rows)
    return rows


if __name__ == "__main__":
    main()
