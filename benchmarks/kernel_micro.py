"""Kernel microbenchmarks.

This container is CPU-only, so the numbers that matter for the TPU target
are STRUCTURAL, not wall-clock: per-kernel VMEM working set, arithmetic
intensity, and the modeled v5e time per call (roofline of the kernel's own
flops/bytes).  Wall-clock here times the pure-jnp reference path on CPU —
useful only as a relative shape-scaling sanity check, clearly labelled.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref, vision_ops
from repro.roofline import HW_V5E

RNG = np.random.default_rng(0)


def _t(*s, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(s), dtype)


def _wall(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6      # us


def flash_attention_model(rows):
    print("\n== flash attention kernel model (v5e) ==")
    print(f"{'B,S,H,D':>18s} {'flops':>10s} {'bytes':>10s} {'AI':>6s} "
          f"{'v5e_us':>8s} {'vmem_kb':>8s} {'cpu_ref_us':>10s}")
    for (B, S, H, D) in [(1, 1024, 8, 128), (1, 4096, 8, 128),
                         (4, 2048, 16, 128)]:
        bq = bk = 256
        flops = 4 * B * H * S * S * D * 0.5             # causal half
        byts = 2 * B * S * H * D * 2 * 3                # q,k,v read + o write
        vmem = (bq + 2 * bk) * D * 2 + bq * (D + 2) * 4
        v5e_us = max(flops / HW_V5E.peak_flops,
                     byts / HW_V5E.hbm_bw) * 1e6
        q = _t(B, S, H, D)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        f = jax.jit(lambda q, p: ref.flash_attention_ref(
            q, q, q, p, p, causal=True))
        cpu = _wall(f, q, pos)
        ai = flops / byts
        print(f"{f'{B},{S},{H},{D}':>18s} {flops:10.2e} {byts:10.2e} "
              f"{ai:6.0f} {v5e_us:8.1f} {vmem / 1024:8.0f} {cpu:10.0f}")
        rows.append((f"fa_{B}x{S}x{H}x{D}", cpu, f"v5e_us={v5e_us:.1f}"))


def decode_attention_model(rows):
    print("\n== flash-decode kernel model (v5e, memory-bound) ==")
    for (B, Hkv, G, D, C) in [(8, 8, 1, 128, 32768), (128, 8, 12, 128, 32768)]:
        flops = 4 * B * Hkv * G * C * D
        byts = B * C * Hkv * D * 2 * 2                  # stream K+V once
        v5e_us = max(flops / HW_V5E.peak_flops, byts / HW_V5E.hbm_bw) * 1e6
        ai = flops / byts
        print(f"B={B} HkvxG={Hkv}x{G} C={C}: AI={ai:.1f} flop/B "
              f"-> v5e {v5e_us:.0f} us/call ({'memory' if ai < 240 else 'compute'}-bound)")
        rows.append((f"dec_{B}x{Hkv}x{G}x{C}", v5e_us, f"AI={ai:.1f}"))


def recurrence_model(rows):
    print("\n== rglru / mlstm kernel model ==")
    B, S, W = 8, 4096, 4096
    byts = 2 * B * S * W * 4 + B * S * W * 4            # a,b read + h write
    flops = 2 * B * S * W
    v5e_us = byts / HW_V5E.hbm_bw * 1e6
    print(f"rglru B={B} S={S} W={W}: AI={flops / byts:.2f} "
          f"(pure streaming) -> v5e {v5e_us:.0f} us")
    rows.append(("rglru_model", v5e_us, f"AI={flops / byts:.2f}"))

    a = jnp.asarray(RNG.uniform(0.5, 0.99, (2, 512, 256)), jnp.float32)
    b = _t(2, 512, 256)
    cpu = _wall(jax.jit(lambda a, b: ref.rglru_scan_ref(a, b)), a, b)
    rows.append(("rglru_cpu_ref", cpu, "jnp associative_scan"))

    B, S, H, Dh, Tc = 1, 4096, 4, 512, 128
    intra = 2 * B * H * S * Tc * Dh * 2
    inter = 2 * B * H * (S // Tc) * (Dh * Dh * Tc * 2)
    byts = 3 * B * S * H * Dh * 2 * 2
    ai = (intra + inter) / byts
    v5e_us = max((intra + inter) / HW_V5E.peak_flops,
                 byts / HW_V5E.hbm_bw) * 1e6
    print(f"mlstm chunkwise Tc={Tc}: AI={ai:.0f} -> v5e {v5e_us:.0f} us "
          f"(vs O(S^2) parallel form: "
          f"{2 * B * H * S * S * Dh * 2 / HW_V5E.peak_flops * 1e6:.0f} us)")
    rows.append(("mlstm_model", v5e_us, f"AI={ai:.0f}"))


def ingest_model(rows):
    """Fused frame-ingest kernel vs the 3-pass jnp baseline it replaces.

    Pure streaming (AI << 1 flop/B): the win is HBM round-trips, so the
    structural number is bytes moved per engine tick.  The jnp baseline
    materialises gate-downscale, block-SAD and model-downscale separately
    (+ the per-lane dynamic_update_slice admission); the fused kernel reads
    the frame batch once and writes (model, gate, score) from VMEM.
    """
    print("\n== fused ingest_frame kernel vs 3-pass jnp baseline (v5e) ==")
    S, C, m, g = 64, 3, 48, 32
    f4 = 4
    gate, model = S * g * g * C * f4, S * m * m * C * f4
    score = S * f4
    print(f"{'S,HxW,dtype':>20s} {'3pass_bytes':>12s} {'fused_bytes':>12s} "
          f"{'reduction':>9s} {'v5e_3pass_us':>12s} {'v5e_fused_us':>12s}")
    for res, dtype, db in ((64, "float32", f4), (256, "float32", f4),
                           (256, "uint8", 1)):
        fb = S * res * res * C * db
        three = ((fb + gate)                    # downscale to gate res
                 + (2 * gate + score)           # block-SAD vs refs
                 + (fb + model)                 # model-res downscale
                 + 2 * (model + gate))          # update_slice loop + refs
        fused = (fb + gate) + (model + gate + score) \
            + 2 * (model + gate)                # one read + scatter_admit
        us3 = three / HW_V5E.hbm_bw * 1e6
        usf = fused / HW_V5E.hbm_bw * 1e6
        tag = f"{S},{res}x{res},{dtype}"
        print(f"{tag:>20s} {three:12.2e} {fused:12.2e} {three / fused:8.1f}x "
              f"{us3:12.2f} {usf:12.2f}")
        rows.append((f"ingest_bytes_reduction_{res}_{dtype}", three / fused,
                     "x_vs_3pass"))
    H = W = 64

    frames = jnp.asarray(RNG.random((S, H, W, C)), jnp.float32)
    refs = jnp.asarray(RNG.random((S, g, g, C)), jnp.float32)
    kw = dict(model_res=m, gate_res=g, block=8)
    f3 = jax.jit(lambda f, r: ref.ingest_frame_ref(f, r, **kw))
    cpu3 = _wall(f3, frames, refs)
    print(f"cpu 3-pass jnp baseline: {cpu3:.0f} us/tick (S={S})")
    rows.append(("ingest_cpu_3pass", cpu3, "us_per_tick_jnp"))

    # differential parity of the fused kernel (interpret mode — wall-clock
    # here is Python interpretation, NOT a perf number; TPU is the target)
    got = vision_ops.ingest_frame(frames, refs, **kw, interpret=True)
    want = f3(frames, refs)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, want))
    print(f"fused-vs-golden parity: max|delta| = {err:.2e}")
    rows.append(("ingest_parity_max_abs_err", err, "vs_jnp_golden"))


def main(rows=None):
    rows = rows if rows is not None else []
    flash_attention_model(rows)
    decode_attention_model(rows)
    recurrence_model(rows)
    ingest_model(rows)
    return rows


if __name__ == "__main__":
    main()
