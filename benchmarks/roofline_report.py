"""Roofline report: aggregate experiments/dryrun/*.json into the §Roofline
table (EXPERIMENTS.md) and pick the hillclimb candidates."""
from __future__ import annotations

import glob
import json
import os


def load(outdir="experiments/dryrun"):
    cells = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def table(cells, mesh="single"):
    rows = []
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c.get("skip"):
            rows.append((c["arch"], c["shape"], "SKIP", "", "", "", "", ""))
            continue
        if not c["ok"]:
            rows.append((c["arch"], c["shape"], "FAIL", "", "", "", "", ""))
            continue
        r = c["roofline"]
        rows.append((c["arch"], c["shape"],
                     f"{r['compute_s']:.2e}", f"{r['memory_s']:.2e}",
                     f"{r['collective_s']:.2e}", r["dominant"],
                     f"{r['useful_ratio']:.2f}",
                     f"{r['roofline_fraction']:.4f}"))
    return rows


def print_table(cells, mesh="single"):
    print(f"\n== roofline baselines ({mesh}-pod) ==")
    hdr = ("arch", "shape", "compute_s", "memory_s", "coll_s", "dominant",
           "useful", "roofline")
    rows = table(cells, mesh)
    w = [max(len(str(r[i])) for r in rows + [hdr]) for i in range(len(hdr))]
    print("  ".join(h.ljust(w[i]) for i, h in enumerate(hdr)))
    for r in rows:
        print("  ".join(str(x).ljust(w[i]) for i, x in enumerate(r)))


def candidates(cells):
    """Pick the three hillclimb cells: worst roofline fraction, most
    collective-bound, most representative of the paper's technique."""
    ok = [c for c in cells if c["mesh"] == "single" and c["ok"]
          and not c.get("skip")]
    if not ok:
        return {}
    worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda c: (c["roofline"]["collective_s"]
                                  / max(c["roofline"]["compute_s"], 1e-12)))
    # the paper's technique is deadline-driven *serving*: the decode cells
    # are its pod analogue; take the biggest-footprint decode cell
    serving = [c for c in ok if c["shape"] in ("decode_32k", "long_500k")]
    rep = max(serving,
              key=lambda c: c["memory"].get("bytes_per_device", 0)) \
        if serving else worst
    return {"worst_roofline": worst, "most_collective": coll,
            "paper_representative": rep}


def main(rows=None):
    rows = rows if rows is not None else []
    cells = load()
    if not cells:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return rows
    for mesh in ("single", "multi"):
        print_table(cells, mesh)
    cands = candidates(cells)
    print("\n== hillclimb candidates ==")
    for why, c in cands.items():
        print(f"{why:22s} {c['arch']} x {c['shape']} "
              f"(dominant={c['roofline']['dominant']}, "
              f"frac={c['roofline']['roofline_fraction']:.4f})")
        rows.append((f"roofline_{why}",
                     c["roofline"]["roofline_fraction"],
                     f"{c['arch']}x{c['shape']}"))
    n_ok = sum(1 for c in cells if c["ok"] and not c.get("skip"))
    n_skip = sum(1 for c in cells if c.get("skip"))
    n_fail = sum(1 for c in cells if not c["ok"])
    print(f"\ncells: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    rows.append(("dryrun_cells_ok", n_ok, f"skip={n_skip},fail={n_fail}"))
    return rows


if __name__ == "__main__":
    main()
