"""Turnaround benchmarks: paper Tables 4.2-4.7 side by side with ours.

One function per paper table; each runs the EDA runtime on the calibrated
device profiles with the dynamic-ESD controller discovering the per-device
ESD (the paper tuned these manually) and prints ours|paper columns.
"""
from __future__ import annotations

from dataclasses import replace

from repro.config import EDAConfig
from repro.core.runtime import EDARuntime, PAPER_DEVICES

from benchmarks import paper_tables as P

N_PAIRS = 300


def _rt(master, workers=(), gran=1.0, simdl=0.35, seg=False):
    m = replace(PAPER_DEVICES[master], dynamic_esd=True)
    ws = [replace(PAPER_DEVICES[w], dynamic_esd=True) for w in workers]
    rt = EDARuntime(eda=EDAConfig(granularity_s=gran,
                                  simulate_download_s=simdl,
                                  segmentation=seg, dynamic_esd=True),
                    master=m, workers=ws)
    rt.run(N_PAIRS)
    return rt


def _fmt(ours, paper):
    return f"{ours:6.0f}|{paper:6.0f}"


def one_node_1s(rows):
    print("\n== Table 4.2: 1 s one-node (ours|paper) ==")
    print(f"{'device':10s} {'proc ms':>13s} {'turn ms':>13s} "
          f"{'esd':>10s} {'skip %':>13s}")
    for name, p in P.T42.items():
        rt = _rt(name)
        s = rt.ledger.summarise()[0]
        esd = rt.esd_values()[name]
        esd = 0.0 if esd <= 1.05 else esd
        print(f"{name:10s} {_fmt(s.processing_ms, p['processing'])} "
              f"{_fmt(s.turnaround_ms, p['turnaround'])} "
              f"{esd:4.1f}|{p['esd']:4.1f} "
              f"{_fmt(100 * s.skip_rate, 100 * p['skip'])}")
        rows.append(("t42_" + name, s.turnaround_ms,
                     f"paper={p['turnaround']}"))


def two_node_1s(rows):
    print("\n== Table 4.3: 1 s two-node (ours|paper turnaround) ==")
    for master, worker, p in P.T43:
        rt = _rt(master.rstrip("*"), [worker])
        by = {s.device: s for s in rt.ledger.summarise()}
        m, w = by[master.rstrip("*")], by[worker]
        print(f"{master:12s} {_fmt(m.turnaround_ms, p['master_turn'])}   "
              f"{worker:10s} {_fmt(w.turnaround_ms, p['worker_turn'])} "
              f"skip {100 * w.skip_rate:4.1f}|{100 * p['worker_skip']:4.1f}%")
        rows.append((f"t43_{master}{worker}", w.turnaround_ms,
                     f"paper={p['worker_turn']}"))


def three_node_1s(rows):
    print("\n== Table 4.4: 1 s three-node + segmentation ==")
    for master, workers, p in P.T44:
        rt = _rt(master.rstrip("*"), list(workers), seg=True)
        by = {s.device: s for s in rt.ledger.summarise()}
        m = by[master.rstrip("*")]
        cols = [f"{master} {_fmt(m.turnaround_ms, p['master_turn'])}"]
        for w, pt in zip(workers, p["worker_turns"]):
            cols.append(f"{w} {_fmt(by[w].turnaround_ms, pt)}")
        print("   ".join(cols))
        rows.append((f"t44_{'_'.join(workers)}", m.turnaround_ms,
                     f"paper={p['master_turn']}"))


def one_node_2s(rows):
    print("\n== Table 4.5: 2 s one-node (ours|paper) ==")
    for name, p in P.T45.items():
        rt = _rt(name, gran=2.0, simdl=0.0)
        s = rt.ledger.summarise()[0]
        print(f"{name:10s} dl {_fmt(s.download_ms, p['download'])} "
              f"proc {_fmt(s.processing_ms, p['processing'])} "
              f"turn {_fmt(s.turnaround_ms, p['turnaround'])} "
              f"skip {100 * s.skip_rate:4.1f}|{100 * p['skip']:4.1f}%")
        rows.append(("t45_" + name, s.turnaround_ms,
                     f"paper={p['turnaround']}"))


def two_node_2s(rows):
    print("\n== Table 4.6: 2 s two-node ==")
    for master, worker, p in P.T46:
        rt = _rt(master.rstrip("*"), [worker], gran=2.0, simdl=0.0)
        by = {s.device: s for s in rt.ledger.summarise()}
        m, w = by[master.rstrip("*")], by[worker]
        print(f"{master:12s} {_fmt(m.turnaround_ms, p['master_turn'])}   "
              f"{worker:10s} {_fmt(w.turnaround_ms, p['worker_turn'])}")
        rows.append((f"t46_{master}{worker}", w.turnaround_ms,
                     f"paper={p['worker_turn']}"))


def three_node_2s(rows):
    print("\n== Table 4.7: 2 s three-node + segmentation (no ESD) ==")
    for master, workers, p in P.T47:
        rt = _rt(master.rstrip("*"), list(workers), gran=2.0, simdl=0.0,
                 seg=True)
        by = {s.device: s for s in rt.ledger.summarise()}
        m = by[master.rstrip("*")]
        esds = rt.esd_values()
        no_esd = all(v <= 1.05 for v in esds.values())
        cols = [f"{master} {_fmt(m.turnaround_ms, p['master_turn'])}"]
        for w, pt in zip(workers, p["worker_turns"]):
            cols.append(f"{w} {_fmt(by[w].turnaround_ms, pt)}")
        print("   ".join(cols) + f"   no-ESD={no_esd} (paper: True)")
        rows.append((f"t47_{'_'.join(workers)}", m.turnaround_ms,
                     f"paper={p['master_turn']},no_esd={no_esd}"))


def main(rows=None):
    rows = rows if rows is not None else []
    one_node_1s(rows)
    two_node_1s(rows)
    three_node_1s(rows)
    one_node_2s(rows)
    two_node_2s(rows)
    three_node_2s(rows)
    return rows


if __name__ == "__main__":
    main()
