"""Benchmark orchestrator: one module per paper table/figure + the
scale/roofline deliverables.  Prints a final ``name,value,derived`` CSV,
optionally writes it to a file and/or a ``BENCH_*.json`` snapshot, and can
gate against a committed baseline (the CI perf gate).

    PYTHONPATH=src python -m benchmarks.run [--only turnaround,...]
        [--csv out.csv] [--json out.json] [--gate BENCH_fleet.json]

Each benchmark module appends ``(name, value, derived)`` rows; a module
that raises is reported and *skipped* — the remaining modules still run,
the partial CSV is still printed, and the exit code goes non-zero at the
end (so CI fails without losing every other module's rows).

JSON schema (``--json``): ``{name: {value, derived, tolerance,
direction}}``.  ``tolerance``/``direction`` come from ``GATE_RULES`` and
say how ``--gate`` compares a current run against the committed baseline:

    higher  regression when value < baseline * (1 - tolerance)
    lower   regression when value > baseline * (1 + tolerance) + floor
    equal   regression when |value - baseline| > tolerance * max(|b|, fl)

Ratio metrics (speedups, parity bits, skip rates) are self-normalising and
get tight tolerances; absolute wall-clock metrics vary wildly across CI
runners and get generous ones.  Metrics without a rule are informational:
recorded in the JSON, never gated.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = ["turnaround", "energy", "esd_sweep", "kernel_micro",
           "serve_bench", "fleet_bench", "scenario_soak",
           "roofline_report"]

# (name-prefix, direction, relative tolerance, absolute floor) — first
# matching prefix wins.  Floors keep near-zero baselines (parity errors)
# from turning a rounding wiggle into a "regression".
GATE_RULES = [
    # correctness bits: exact
    ("fleet_parallel_parity", "equal", 0.0, 0.0),
    ("fleet_mixed_tier_parity", "equal", 0.0, 0.0),
    ("fleet_ingest_parity", "equal", 0.0, 0.0),
    ("fleet_obs_parity", "equal", 0.0, 0.0),
    ("fleet_event_parity", "equal", 0.0, 0.0),
    ("fleet_scale_parity", "equal", 0.0, 0.0),
    ("scenario_soak_deterministic", "equal", 0.0, 0.0),
    ("scenario_soak_violations", "equal", 0.0, 0.0),
    # obs-overhead wall ratio: generous tolerance (tiny CPU workload,
    # registry updates are a visible tick fraction) — catches the obs
    # plane ever turning into a per-tick multiplier
    ("fleet_obs_overhead", "lower", 0.50, 0.0),
    # event-plane wall ratio: same shape as the obs gate — envelope
    # construction + cooldown checks + the per-tick pump are host-side
    # dict work; the gate catches the plane becoming a tick multiplier
    ("fleet_event_overhead", "lower", 0.50, 0.0),
    # spool-drain throughput (partition -> reconnect -> flush): pure
    # host-side dict/deque work, machine-class dependent — catastrophic
    # slowdowns only
    ("fleet_event_drain_eps", "higher", 0.75, 0.0),
    # self-normalising ratios: the core perf-trajectory signals
    ("fleet_parallel_speedup", "higher", 0.30, 0.0),
    ("fleet_batching_speedup", "higher", 0.35, 0.0),
    ("fleet_gate_speedup", "higher", 0.35, 0.0),
    ("serve_batching_speedup", "higher", 0.35, 0.0),
    ("serve_paged_batching_speedup", "higher", 0.35, 0.0),
    # paged-vs-dense greedy token streams must stay identical (within the
    # sliding window, where the contiguous ring is exact)
    ("serve_paged_token_parity", "equal", 0.0, 0.02),
    ("fleet_gate_skip_rate", "equal", 0.15, 0.0),
    ("ingest_bytes_reduction_", "equal", 0.02, 0.0),
    ("ingest_parity_max_abs_err", "lower", 1.0, 1e-5),
    # absolute wall-clock / throughput: the committed baseline and a CI
    # runner are different machine classes, so these only catch
    # catastrophic (several-x) slowdowns — the ratio metrics above are
    # the real per-PR signal
    ("fleet_serial_fps", "higher", 0.75, 0.0),
    ("fleet_parallel_fps", "higher", 0.75, 0.0),
    ("fleet_mixed_tier_fps", "higher", 0.75, 0.0),
    ("fleet_slots", "lower", 2.0, 0.0),
    # hierarchical host time per frame: absolute wall on a CI runner, so
    # catastrophic-only — the in-bench 64r <= 2x 8r sublinearity assert
    # is the hard bar
    ("fleet_host_us_per_frame", "lower", 3.0, 0.0),
    ("fleet_streams", "higher", 0.75, 0.0),
    ("fleet_ingest_", "higher", 0.75, 0.0),
    ("ingest_cpu_3pass", "lower", 3.0, 0.0),
    ("fa_", "lower", 3.0, 0.0),
    # token-engine wall-clock (serve_bench): catastrophic-only, like the
    # other absolute metrics; the esd skip rates stay informational (they
    # depend on measured per-token cost, which is machine-class noise)
    ("serve_decode_us_per_token", "lower", 3.0, 0.0),
    ("serve_paged_decode_us_per_token", "lower", 3.0, 0.0),
    ("serve_ttft_", "lower", 3.0, 0.0),
    ("serve_turnaround_", "lower", 3.0, 0.0),
]


def rule_for(name: str):
    for prefix, direction, tol, floor in GATE_RULES:
        if name.startswith(prefix):
            return direction, tol, floor
    return None


def gate(rows, baseline: dict):
    """Compare current rows to a committed baseline.  Returns (regressions,
    verdict lines).  A gated baseline metric that is *missing* from the
    current run counts as a regression — a refactor that silently stops
    emitting fleet_parallel_speedup must not turn the gate green — so a
    --gate run has to select the same module set the baseline was built
    from (see benchmarks/README.md).  Metrics new in the current run are
    informational (they land with their own fresh baseline)."""
    current = {name: value for name, value, _ in rows}
    regressions, lines = [], []
    for name, entry in sorted(baseline.items()):
        base = float(entry["value"])
        rule = rule_for(name)
        if rule is None:
            continue
        direction, tol, floor = rule
        if name not in current:
            lines.append(f"  REGRESSION {name}: missing from current run "
                         f"(baseline {base:g}) — a gated metric vanished")
            regressions.append(name)
            continue
        cur = float(current[name])
        if direction == "higher":
            bad = cur < base * (1.0 - tol) - floor
        elif direction == "lower":
            bad = cur > base * (1.0 + tol) + floor
        else:
            bad = abs(cur - base) > tol * max(abs(base), floor) \
                + (floor if tol == 0.0 else 0.0)
        verdict = "REGRESSION" if bad else "ok"
        lines.append(f"  {verdict:10s} {name}: {cur:g} vs baseline "
                     f"{base:g} ({direction}, tol {tol:g})")
        if bad:
            regressions.append(name)
    fresh = sorted(set(current) - set(baseline))
    for name in fresh:
        lines.append(f"  NEW        {name}: {current[name]:g} "
                     f"(no baseline yet)")
    return regressions, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--csv", default="",
                    help="also write the CSV rows to this file")
    ap.add_argument("--json", default="",
                    help="write {name: {value, derived, tolerance, "
                         "direction}} snapshot (the BENCH_*.json schema)")
    ap.add_argument("--gate", default="",
                    help="baseline JSON to compare against; exits non-zero "
                         "on any per-metric tolerance regression")
    args = ap.parse_args(argv)
    only = [s for s in args.only.split(",") if s]

    rows = []
    failures = []
    for name in (only or MODULES):
        print(f"\n######## {name} ########")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(rows)
        except Exception:
            # one failing module must not swallow the others' rows
            traceback.print_exc()
            failures.append(name)
            print(f"[{name}: FAILED after {time.time() - t0:.1f}s — "
                  f"continuing with remaining modules]")
            continue
        print(f"[{name}: {time.time() - t0:.1f}s]")

    print("\n======== CSV ========")
    csv_lines = ["name,value,derived"]
    csv_lines += [f"{name},{value},{derived}" for name, value, derived in rows]
    print("\n".join(csv_lines))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("\n".join(csv_lines) + "\n")

    if args.json:
        snapshot = {}
        for name, value, derived in rows:
            rule = rule_for(name)
            snapshot[name] = {
                "value": float(value), "derived": str(derived),
                "tolerance": rule[1] if rule else None,
                "direction": rule[0] if rule else None,
            }
        with open(args.json, "w") as f:
            json.dump(snapshot, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[wrote {len(snapshot)} metrics to {args.json}]")

    regressions = []
    if args.gate:
        with open(args.gate) as f:
            baseline = json.load(f)
        regressions, lines = gate(rows, baseline)
        print(f"\n======== gate vs {args.gate} ========")
        print("\n".join(lines))
        print(f"[{len(regressions)} regression(s), "
              f"{len(failures)} failed module(s)]")

    if failures:
        print(f"\nFAILED modules: {', '.join(failures)}", file=sys.stderr)
    if regressions:
        print(f"PERF REGRESSIONS: {', '.join(regressions)}",
              file=sys.stderr)
    return 1 if (failures or regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
