"""Benchmark orchestrator: one module per paper table/figure + the
scale/roofline deliverables.  Prints a final ``name,value,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only turnaround,...]
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = ["turnaround", "energy", "esd_sweep", "kernel_micro",
           "serving_bench", "fleet_bench", "scenario_soak",
           "roofline_report"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    rows = []
    for name in (only or MODULES):
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        print(f"\n######## {name} ########")
        t0 = time.time()
        mod.main(rows)
        print(f"[{name}: {time.time() - t0:.1f}s]")

    print("\n======== CSV ========")
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
